"""repro-audit runner package — see ``python -m tools.audit.run --help``."""
