#!/usr/bin/env python
"""repro-audit: the static contract analyzer's one entry point.

    python -m tools.audit.run                      # all five passes
    python -m tools.audit.run --passes layering,keys,pallas,docs
    python -m tools.audit.run --quick              # small lowered matrix
    python -m tools.audit.run --json report.json --fail-on-violation

Passes (docs/analysis.md; implementations in src/repro/analysis/):
layering, keys, pallas, docs are pure-AST/filesystem and run in well under
a second; ``lowered`` traces and lowers every serving program over the
{ring,paged} x {gather,xla,pallas} x {self,proxy} x delta-regime matrix
(~40 s on CPU; ``--quick`` restricts it to two cells for smoke runs).

Exit status: 0 when every selected pass is clean, 1 with
``--fail-on-violation`` otherwise (CI runs it with the flag; a human run
always exits 0 so the report can be read without shell gymnastics).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[2]

# self-bootstrapping: CI's docs/audit jobs invoke tools scripts without
# PYTHONPATH=src, and the lowered pass must not grab a real accelerator
sys.path.insert(0, str(REPO / "src"))
os.environ.setdefault("JAX_PLATFORMS", "cpu")


def main(argv=None) -> int:
    from repro.analysis import PASS_NAMES, run_passes

    ap = argparse.ArgumentParser(
        prog="python -m tools.audit.run",
        description="static contract analyzer for the serving stack")
    ap.add_argument("--passes", default=",".join(PASS_NAMES),
                    help=f"comma-separated subset of: {', '.join(PASS_NAMES)}")
    ap.add_argument("--quick", action="store_true",
                    help="lowered pass: 2 cells instead of the full matrix")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write the machine-readable report here")
    ap.add_argument("--fail-on-violation", action="store_true",
                    help="exit 1 if any pass reports a violation (CI mode)")
    args = ap.parse_args(argv)

    names = [n.strip() for n in args.passes.split(",") if n.strip()]
    unknown = [n for n in names if n not in PASS_NAMES]
    if unknown:
        ap.error(f"unknown pass(es): {', '.join(unknown)}")

    results = run_passes(names, REPO, quick=args.quick)

    n_viol = 0
    for r in results:
        mark = "ok  " if r.ok else "FAIL"
        stat = " ".join(f"{k}={v}" for k, v in r.stats.items()
                        if not isinstance(v, (list, dict)))
        print(f"[{mark}] {r.name:<10} {stat}")
        for v in r.violations:
            print(f"       {v}")
        n_viol += len(r.violations)

    print(f"\naudit: {len(results)} passes, {n_viol} violations")
    if args.json:
        report = {"passes": [r.to_json() for r in results],
                  "violations": n_viol}
        Path(args.json).write_text(json.dumps(report, indent=2) + "\n",
                                   encoding="utf-8")
        print(f"wrote {args.json}")
    return 1 if (n_viol and args.fail_on_violation) else 0


if __name__ == "__main__":
    sys.exit(main())
