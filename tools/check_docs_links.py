#!/usr/bin/env python
"""Fail on broken relative links in README.md and docs/*.md.

Checks every markdown inline link ``[text](target)``:
  * http(s)/mailto targets are skipped (no network in CI);
  * pure-anchor targets (``#section``) are skipped;
  * everything else must resolve to an existing file or directory
    relative to the file containing the link (any ``#anchor`` suffix is
    stripped first).

Run:  python tools/check_docs_links.py   (exit 1 + listing on failure)
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")


def doc_files() -> list[Path]:
    files = [REPO / "README.md"]
    files += sorted((REPO / "docs").glob("*.md"))
    return [f for f in files if f.exists()]


def check(path: Path) -> list[str]:
    errors = []
    text = path.read_text(encoding="utf-8")
    for lineno, line in enumerate(text.splitlines(), 1):
        for m in LINK_RE.finditer(line):
            target = m.group(1)
            if target.startswith(SKIP_PREFIXES):
                continue
            rel = target.split("#", 1)[0]
            if not rel:
                continue
            resolved = (path.parent / rel).resolve()
            if not resolved.exists():
                errors.append(f"{path.relative_to(REPO)}:{lineno}: "
                              f"broken link -> {target}")
    return errors


def main() -> int:
    files = doc_files()
    if not files:
        print("no docs found to check", file=sys.stderr)
        return 1
    errors = [e for f in files for e in check(f)]
    for e in errors:
        print(e, file=sys.stderr)
    n_links = sum(len(LINK_RE.findall(f.read_text(encoding="utf-8")))
                  for f in files)
    print(f"checked {len(files)} files / {n_links} links: "
          f"{len(errors)} broken")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
