#!/usr/bin/env python
"""Fail on broken relative links in README.md and docs/*.md.

Thin shim: the check itself moved into the static analyzer as its ``docs``
pass (``src/repro/analysis/docs_links.py``; run all passes with
``python -m tools.audit.run``).  This entry point keeps the historical CLI
and exit-code contract for existing CI invocations.
"""
from __future__ import annotations

import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "src"))


def main() -> int:
    from repro.analysis.docs_links import run

    result = run(REPO)
    for v in result.violations:
        print(f"{v.where}: broken link -> {v.detail.split(': ', 1)[-1]}",
              file=sys.stderr)
    print(f"checked {result.stats['files']} files / "
          f"{result.stats['links']} links: {len(result.violations)} broken")
    return 1 if result.violations else 0


if __name__ == "__main__":
    sys.exit(main())
