"""Batched reasoning-serving engine with EAT early exit.

The engine drives a host-side loop around jitted step functions:

  prefill -> [decode token -> (due?) EAT probe -> monitor update -> exit?]*
          -> forced answer rollout (GenTillEoS with ``</think>`` appended)

Per-sequence adaptivity in a batched TPU loop (DESIGN.md §4.4): exited
sequences stay in their slots with ``active=False`` — their sampled tokens
are replaced by PAD, their monitor state freezes, and cache writes become
don't-cares (nothing reads a finished sequence's future slots).

The same machinery provides the paper's evaluation harness:
``reason_with_trace`` generates one long chain and records, at every
evaluation point, EAT / confidence / forced-rollout answers — the offline
"simulated early exiting" protocol of App. H.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.eat import ProbeSpec, eval_eat
from repro.core.monitor import MonitorState, ReasoningMonitor
from repro.models.model import Model
from repro.serving.cache import alloc_cache
from repro.serving.sampler import SamplerConfig, logprob_of, sample


class ServeState(NamedTuple):
    cache: dict
    rng: jax.Array
    active: jax.Array          # (B,) still reasoning
    next_pos: jax.Array        # (B,) next token position (left-pad aware)
    last_token: jax.Array      # (B,)
    n_reasoning: jax.Array     # (B,) reasoning tokens generated
    monitor: MonitorState
    ended_think: jax.Array     # (B,) emitted </think> naturally
    out_tokens: jax.Array      # (B, T_buf) generated reasoning tokens
    out_len: jax.Array         # (B,)


@dataclasses.dataclass
class EngineConfig:
    max_reasoning_tokens: int = 1024
    capacity: int = 2048                 # cache slots
    pad_id: int = 0
    end_think_id: int = 1
    newline_id: int = 2
    eos_id: int = 3
    sampler: SamplerConfig = dataclasses.field(default_factory=SamplerConfig)


class ReasoningEngine:
    """White-box engine: the reasoning model is also the EAT monitor model."""

    def __init__(self, model: Model, params, ecfg: EngineConfig,
                 monitor: ReasoningMonitor | None = None):
        from repro.core.stopping import EATStopper

        self.model = model
        self.params = params
        self.ecfg = ecfg
        if monitor is None:
            monitor = ReasoningMonitor(
                stopper=EATStopper(),
                probe=ProbeSpec((ecfg.end_think_id,)),
                newline_id=ecfg.newline_id,
            )
        self.monitor = monitor
        cfg = model.cfg

        def _positions(pos1d):
            if cfg.mrope_sections:
                return jnp.broadcast_to(pos1d[..., None], pos1d.shape + (3,))
            return pos1d

        self._positions = _positions

        @jax.jit
        def decode_fn(params, state: ServeState):
            tok = state.last_token[:, None]
            pos1d = state.next_pos[:, None]
            logits, cache = model.decode_step(
                params, tok, _positions(pos1d), pos1d, state.cache
            )
            rng, sub = jax.random.split(state.rng)
            nxt = sample(sub, logits[:, -1], cfg.vocab, ecfg.sampler)
            nxt = jnp.where(state.active, nxt, ecfg.pad_id)
            ended = state.ended_think | (state.active & (nxt == ecfg.end_think_id))
            # append at out_len via scatter
            out_tokens = state.out_tokens.at[
                jnp.arange(nxt.shape[0]), state.out_len
            ].set(jnp.where(state.active, nxt, ecfg.pad_id))
            return state._replace(
                cache=cache,
                rng=rng,
                next_pos=state.next_pos + state.active.astype(jnp.int32),
                last_token=nxt,
                n_reasoning=state.n_reasoning + state.active.astype(jnp.int32),
                ended_think=ended,
                out_tokens=out_tokens,
                out_len=state.out_len + state.active.astype(jnp.int32),
            )

        self._decode_fn = decode_fn

        if monitor is not None:
            @jax.jit
            def probe_fn(params, cache, next_pos):
                return eval_eat(model, params, cache, monitor.probe, next_pos)

            self._probe_fn = probe_fn

        @functools.partial(jax.jit, static_argnames=("n", "greedy"))
        def rollout_fn(params, cache, next_pos, last_token, rng, *, n: int,
                       greedy: bool = False):
            """Forced answer rollout: append </think> then generate n tokens.
            Cache changes are local to this call (functional).  Returns
            (tokens (B,n), logprobs (B,n))."""
            B = next_pos.shape[0]
            et = jnp.full((B, 1), ecfg.end_think_id, jnp.int32)
            pos1d = next_pos[:, None]
            logits, cache2 = model.decode_step(params, et, _positions(pos1d), pos1d, cache)
            scfg = dataclasses.replace(ecfg.sampler, greedy=greedy)

            def step(carry, _):
                cache_c, pos_c, logit_c, rng_c = carry
                rng_c, sub = jax.random.split(rng_c)
                tok = sample(sub, logit_c, cfg.vocab, scfg)
                lp = logprob_of(logit_c, tok, cfg.vocab)
                p1 = pos_c[:, None]
                lg, cache_c = model.decode_step(
                    params, tok[:, None], _positions(p1), p1, cache_c
                )
                return (cache_c, pos_c + 1, lg[:, -1], rng_c), (tok, lp)

            (_, _, _, _), (toks, lps) = jax.lax.scan(
                step, (cache2, next_pos + 1, logits[:, -1], rng), None, length=n
            )
            return jnp.moveaxis(toks, 0, 1), jnp.moveaxis(lps, 0, 1)

        self._rollout_fn = rollout_fn

    # ------------------------------------------------------------- prefill
    def start(self, prompts: jax.Array, prompt_len: jax.Array, rng,
              *, frames=None, image_embeds=None) -> ServeState:
        """prompts: (B, S) LEFT-padded token ids; prompt_len: (B,).

        Positions are 0..len-1 per sequence (pad slots get -1 = masked).
        """
        model, ecfg = self.model, self.ecfg
        B, S = prompts.shape
        pad = S - prompt_len                                # (B,)
        pos1d = jnp.arange(S, dtype=jnp.int32)[None, :] - pad[:, None]
        pos1d = jnp.where(pos1d >= 0, pos1d, -1)
        n_img = 0
        if image_embeds is not None:
            n_img = image_embeds.shape[1]
            img_pos = jnp.broadcast_to(
                jnp.arange(n_img, dtype=jnp.int32)[None], (B, n_img)
            )
            pos1d = jnp.concatenate([img_pos, jnp.where(pos1d >= 0, pos1d + n_img, -1)], 1)
        cache = alloc_cache(model.cfg, B, ecfg.capacity)
        hidden, cache = jax.jit(model.prefill)(
            self.params, prompts, self._positions(pos1d), pos1d, cache,
            frames=frames, image_embeds=image_embeds,
        )
        next_pos = prompt_len + n_img
        logits_last = self.model.logits(self.params, hidden[:, -1:])[:, 0]
        rng, sub = jax.random.split(rng)
        first = sample(sub, logits_last, model.cfg.vocab, ecfg.sampler)
        buf = jnp.full((B, ecfg.max_reasoning_tokens + 8), ecfg.pad_id, jnp.int32)
        buf = buf.at[:, 0].set(first)
        mon = self.monitor.init(B)
        return ServeState(
            cache=cache,
            rng=rng,
            active=jnp.ones((B,), bool),
            next_pos=next_pos.astype(jnp.int32),
            last_token=first,
            n_reasoning=jnp.ones((B,), jnp.int32),
            monitor=mon,
            ended_think=(first == ecfg.end_think_id),
            out_tokens=buf,
            out_len=jnp.ones((B,), jnp.int32),
        )

    # ------------------------------------------------------------- loop
    def reason(self, state: ServeState, *, max_tokens: int | None = None,
               use_monitor: bool = True) -> ServeState:
        """Run the reasoning loop until all sequences exit (EAT stop, natural
        </think>, or token budget)."""
        ecfg = self.ecfg
        budget = max_tokens or ecfg.max_reasoning_tokens
        while bool(state.active.any()) and int(state.n_reasoning.max()) < budget:
            state = self._decode_fn(self.params, state)
            if self.monitor is not None and use_monitor:
                due = self.monitor.due(state.monitor, state.last_token)
                if bool((due & state.active).any()):
                    eat = self._probe_fn(self.params, state.cache, state.next_pos)
                    mon = self.monitor.update(state.monitor, eat, due, state.active)
                    state = state._replace(monitor=mon)
                else:
                    state = state._replace(
                        monitor=self.monitor.tick_no_eval(state.monitor, state.active)
                    )
                exits = state.monitor.stop_flag
            else:
                exits = jnp.zeros_like(state.active)
            over = state.n_reasoning >= budget
            state = state._replace(active=state.active & ~exits & ~state.ended_think & ~over)
        return state

    # ------------------------------------------------------------- answers
    def force_answer(self, state: ServeState, n_tokens: int, rng=None,
                     *, greedy: bool = False):
        """GenTillEoS(Q, <think>, R, </think>; theta) — Eq. (10)/Alg. 1 line 11.
        Returns (tokens (B,n), logprobs (B,n))."""
        rng = rng if rng is not None else state.rng
        return self._rollout_fn(
            self.params, state.cache, state.next_pos, state.last_token, rng,
            n=n_tokens, greedy=greedy,
        )

    def rollout_answers(self, state: ServeState, k: int, n_tokens: int, rng):
        """K independent forced rollouts (for Pass@1 / #UA@K).  Returns
        tokens (K, B, n)."""
        rngs = jax.random.split(rng, k)
        outs = [self._rollout_fn(self.params, state.cache, state.next_pos,
                                 state.last_token, r, n=n_tokens)[0]
                for r in rngs]
        return jnp.stack(outs)

    def eval_eat_now(self, state: ServeState) -> jax.Array:
        return self._probe_fn(self.params, state.cache, state.next_pos)

    # ------------------------------------------------------------- tracing
    def reason_with_trace(
        self, state: ServeState, *, max_tokens: int, rollout_k: int = 0,
        rollout_len: int = 8, answer_extract: Optional[Callable] = None,
        confidence_len: int = 0,
    ) -> tuple[ServeState, list[dict]]:
        """Generate one long chain; at every due point record EAT (and
        optionally K rollout answers + confidence).  The offline evaluation
        protocol of App. H — no early exit is taken."""
        trace: list[dict] = []
        rng = state.rng
        while bool(state.active.any()) and int(state.n_reasoning.max()) < max_tokens:
            state = self._decode_fn(self.params, state)
            due = (self.monitor.due(state.monitor, state.last_token)
                   if self.monitor is not None
                   else state.last_token == self.ecfg.newline_id)
            if bool((due & state.active).any()):
                rec: dict = {
                    "n_tokens": np.asarray(state.n_reasoning),
                    "due": np.asarray(due & state.active),
                    "eat": np.asarray(self.eval_eat_now(state)),
                }
                if rollout_k:
                    rng, sub = jax.random.split(rng)
                    rolls = self.rollout_answers(state, rollout_k, rollout_len, sub)
                    rec["rollouts"] = np.asarray(rolls)
                    if answer_extract is not None:
                        rec["answers"] = np.stack(
                            [answer_extract(np.asarray(rolls[i])) for i in range(rollout_k)]
                        )
                if confidence_len:
                    _, lps = self.force_answer(state, confidence_len, greedy=True)
                    rec["confidence"] = np.asarray(jnp.exp(lps.mean(-1)))
                if self.monitor is not None:
                    mon = self.monitor.update(state.monitor, jnp.asarray(rec["eat"]),
                                              due, state.active)
                    state = state._replace(monitor=mon)
                    rec["ema_var"] = np.asarray(
                        self.monitor.stopper.debiased_var(mon.stop_state)
                    )
                trace.append(rec)
            state = state._replace(active=state.active & ~state.ended_think)
        return state, trace
