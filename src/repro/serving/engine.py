"""Batched reasoning-serving engine with EAT early exit.

Device-resident chunked decode (DESIGN.md §4.4 + this PR):

  prefill -> [decode_chunk]* -> forced answer rollout (GenTillEoS)

``decode_chunk`` is ONE jitted dispatch that advances up to ``chunk_len``
tokens with a ``jax.lax.while_loop`` whose body is the unified EAT step
(``launch.serve_step.make_eat_step`` — the same program the dry-runs
lower): sampling, the non-committing ``</think>``+prefix probe (under
``lax.cond`` so chunks with no due evaluation pay zero probe FLOPs), the
EMA monitor update, ``</think>`` detection, the token-budget check, and
exit latching are all masked array ops.  The host syncs once per chunk
(``state.active.any()``) instead of twice per token — the old per-token
loop is kept as ``_reason_per_token`` and raced by
``benchmarks/engine_throughput.py``.

Per-sequence adaptivity in a batched TPU loop: exited sequences stay in
their slots with ``active=False`` — their sampled tokens are replaced by
PAD, their monitor state freezes, and cache writes become don't-cares
(nothing reads a finished sequence's future slots).

Continuous batching (``serve``): a slot-based admission queue on top of the
chunked loop.  When a sequence exits early its result is harvested and its
batch slot is immediately recycled: the next queued prompt is prefilled
alone (B=1 ``start``) and row-merged into the live state —
``cache.merge_cache_row`` overwrites the slot's KV rows/positions wholesale
and advances the shared ring pointer to ``max(cur, prompt_len)``, so the
admitted sequence's KV (slots ``0..P-1``) and its future decode writes
(slots ``>= cur``) never collide until the ring wraps; ``EngineConfig
.capacity`` must therefore cover the batch-lifetime token count, as in the
per-batch setting.  The batch stays full under sustained traffic instead of
draining to the slowest sequence.

The same machinery provides the paper's evaluation harness:
``reason_with_trace`` generates one long chain and records, at every
evaluation point, EAT / confidence / forced-rollout answers — the offline
"simulated early exiting" protocol of App. H.  It reuses the chunked step
with ``chunk_len`` tuned to the evaluation schedule (1 for the paragraph
schedule, ``every_n`` for the fixed-stride schedule) so its per-evaluation
host hooks still fire between chunks.
"""
from __future__ import annotations

import dataclasses
import functools
from collections import deque
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.eat import ProbeSpec, eval_eat
from repro.core.monitor import MonitorState, ReasoningMonitor
from repro.launch.serve_step import make_eat_step
from repro.models.model import Model
from repro.serving.cache import alloc_cache, freeze_inactive_rows, merge_cache_row
from repro.serving.sampler import SamplerConfig, logprob_of, sample


class ServeState(NamedTuple):
    cache: dict
    rng: jax.Array
    active: jax.Array          # (B,) still reasoning
    next_pos: jax.Array        # (B,) next token position (left-pad aware)
    last_token: jax.Array      # (B,)
    n_reasoning: jax.Array     # (B,) reasoning tokens generated
    monitor: MonitorState
    ended_think: jax.Array     # (B,) emitted </think> naturally
    out_tokens: jax.Array      # (B, T_buf) generated reasoning tokens
    out_len: jax.Array         # (B,)


@dataclasses.dataclass
class EngineConfig:
    max_reasoning_tokens: int = 1024
    capacity: int = 2048                 # cache slots
    pad_id: int = 0
    end_think_id: int = 1
    newline_id: int = 2
    eos_id: int = 3
    chunk_len: int = 32                  # decode steps per jitted dispatch
    sampler: SamplerConfig = dataclasses.field(default_factory=SamplerConfig)


class ReasoningEngine:
    """White-box engine: the reasoning model is also the EAT monitor model."""

    def __init__(self, model: Model, params, ecfg: EngineConfig,
                 monitor: ReasoningMonitor | None = None):
        from repro.core.stopping import EATStopper

        self.model = model
        self.params = params
        self.ecfg = ecfg
        if monitor is None:
            monitor = ReasoningMonitor(
                stopper=EATStopper(),
                probe=ProbeSpec((ecfg.end_think_id,)),
                newline_id=ecfg.newline_id,
            )
        self.monitor = monitor
        cfg = model.cfg

        def _positions(pos1d):
            if cfg.mrope_sections:
                return jnp.broadcast_to(pos1d[..., None], pos1d.shape + (3,))
            return pos1d

        self._positions = _positions

        # the unified per-token program (shared with the dry-run lowering)
        step_mon = make_eat_step(model, monitor, ecfg.sampler, probe_cond=True)
        step_plain = make_eat_step(model, None, ecfg.sampler)

        def _advance(params, state: ServeState, budget, step_fn) -> ServeState:
            """One monitored decode step + engine bookkeeping, all masked."""
            tok = state.last_token[:, None]
            # inactive rows still ride through the batched step, but their
            # KV write must be invisible: pos=-1 keeps the duplicate-position
            # entry out of every later attention mask (q_pos >= kv_pos >= 0)
            pos1d = jnp.where(state.active, state.next_pos, -1)[:, None]
            nxt, cache, mon, stop, rng = step_fn(
                params, state.cache, tok, pos1d, state.monitor,
                state.active, state.rng,
            )
            if cfg.arch_type in ("ssm", "hybrid"):
                cache = freeze_inactive_rows(cache, state.cache, state.active)
            nxt = jnp.where(state.active, nxt, ecfg.pad_id)
            ended = state.ended_think | (state.active & (nxt == ecfg.end_think_id))
            out_tokens = state.out_tokens.at[
                jnp.arange(nxt.shape[0]), state.out_len
            ].set(nxt)
            inc = state.active.astype(jnp.int32)
            n_reasoning = state.n_reasoning + inc
            over = n_reasoning >= budget
            return ServeState(
                cache=cache,
                rng=rng,
                active=state.active & ~stop & ~ended & ~over,
                next_pos=state.next_pos + inc,
                last_token=nxt,
                n_reasoning=n_reasoning,
                monitor=mon,
                ended_think=ended,
                out_tokens=out_tokens,
                out_len=state.out_len + inc,
            )

        def _make_chunk(step_fn):
            def chunk(params, state: ServeState, budget, chunk_len):
                def cond(carry):
                    i, st = carry
                    return (i < chunk_len) & st.active.any()

                def body(carry):
                    i, st = carry
                    return i + 1, _advance(params, st, budget, step_fn)

                _, state = jax.lax.while_loop(
                    cond, body, (jnp.zeros((), jnp.int32), state)
                )
                return state

            return jax.jit(chunk)

        self._chunk_mon = _make_chunk(step_mon)
        self._chunk_plain = _make_chunk(step_plain)

        @jax.jit
        def decode_fn(params, state: ServeState):
            """One unmonitored decode step — _advance with no budget (kept
            as the per-token baseline for benchmarks/engine_throughput.py
            and unit tests, so the two paths can never diverge)."""
            no_budget = jnp.asarray(jnp.iinfo(jnp.int32).max, jnp.int32)
            return _advance(params, state, no_budget, step_plain)

        self._decode_fn = decode_fn
        # one persistent jit wrapper so start() (and every B=1 slot
        # admission in serve()) reuses the compiled prefill per batch shape
        self._prefill_fn = jax.jit(model.prefill)

        @jax.jit
        def probe_fn(params, cache, next_pos):
            return eval_eat(model, params, cache, monitor.probe, next_pos)

        self._probe_fn = probe_fn

        @jax.jit
        def admit_fn(state: ServeState, one: ServeState, slot) -> ServeState:
            """Recycle a batch slot: overwrite row ``slot`` of every per-
            sequence array (and the cache row, see ``merge_cache_row``) with
            the freshly-prefilled single-sequence state ``one``.  Jitted so
            admission is one fused dispatch, not an eager op-by-op copy of
            the whole cache."""

            def put(big, small):
                return big.at[slot].set(small[0])

            return ServeState(
                cache=merge_cache_row(state.cache, one.cache, slot),
                rng=state.rng,
                active=put(state.active, one.active),
                next_pos=put(state.next_pos, one.next_pos),
                last_token=put(state.last_token, one.last_token),
                n_reasoning=put(state.n_reasoning, one.n_reasoning),
                monitor=jax.tree_util.tree_map(put, state.monitor, one.monitor),
                ended_think=put(state.ended_think, one.ended_think),
                out_tokens=put(state.out_tokens, one.out_tokens),
                out_len=put(state.out_len, one.out_len),
            )

        self._admit_fn = admit_fn

        @functools.partial(jax.jit, static_argnames=("n", "greedy"))
        def rollout_fn(params, cache, next_pos, last_token, rng, *, n: int,
                       greedy: bool = False):
            """Forced answer rollout: append </think> then generate n tokens.
            Cache changes are local to this call (functional).  Returns
            (tokens (B,n), logprobs (B,n))."""
            B = next_pos.shape[0]
            et = jnp.full((B, 1), ecfg.end_think_id, jnp.int32)
            pos1d = next_pos[:, None]
            logits, cache2 = model.decode_step(params, et, _positions(pos1d), pos1d, cache)
            scfg = dataclasses.replace(ecfg.sampler, greedy=greedy)

            def step(carry, _):
                cache_c, pos_c, logit_c, rng_c = carry
                rng_c, sub = jax.random.split(rng_c)
                tok = sample(sub, logit_c, cfg.vocab, scfg)
                lp = logprob_of(logit_c, tok, cfg.vocab)
                p1 = pos_c[:, None]
                lg, cache_c = model.decode_step(
                    params, tok[:, None], _positions(p1), p1, cache_c
                )
                return (cache_c, pos_c + 1, lg[:, -1], rng_c), (tok, lp)

            (_, _, _, _), (toks, lps) = jax.lax.scan(
                step, (cache2, next_pos + 1, logits[:, -1], rng), None, length=n
            )
            return jnp.moveaxis(toks, 0, 1), jnp.moveaxis(lps, 0, 1)

        self._rollout_fn = rollout_fn

    # ------------------------------------------------------------- prefill
    def start(self, prompts: jax.Array, prompt_len: jax.Array, rng,
              *, frames=None, image_embeds=None) -> ServeState:
        """prompts: (B, S) LEFT-padded token ids; prompt_len: (B,).

        Positions are 0..len-1 per sequence (pad slots get -1 = masked).
        """
        model, ecfg = self.model, self.ecfg
        B, S = prompts.shape
        pad = S - prompt_len                                # (B,)
        pos1d = jnp.arange(S, dtype=jnp.int32)[None, :] - pad[:, None]
        pos1d = jnp.where(pos1d >= 0, pos1d, -1)
        n_img = 0
        if image_embeds is not None:
            n_img = image_embeds.shape[1]
            img_pos = jnp.broadcast_to(
                jnp.arange(n_img, dtype=jnp.int32)[None], (B, n_img)
            )
            pos1d = jnp.concatenate([img_pos, jnp.where(pos1d >= 0, pos1d + n_img, -1)], 1)
        cache = alloc_cache(model.cfg, B, ecfg.capacity)
        hidden, cache = self._prefill_fn(
            self.params, prompts, self._positions(pos1d), pos1d, cache,
            frames=frames, image_embeds=image_embeds,
        )
        next_pos = prompt_len + n_img
        logits_last = self.model.logits(self.params, hidden[:, -1:])[:, 0]
        rng, sub = jax.random.split(rng)
        first = sample(sub, logits_last, model.cfg.vocab, ecfg.sampler)
        buf = jnp.full((B, ecfg.max_reasoning_tokens + 8), ecfg.pad_id, jnp.int32)
        buf = buf.at[:, 0].set(first)
        mon = self.monitor.init(B)
        return ServeState(
            cache=cache,
            rng=rng,
            active=jnp.ones((B,), bool),
            next_pos=next_pos.astype(jnp.int32),
            last_token=first,
            n_reasoning=jnp.ones((B,), jnp.int32),
            monitor=mon,
            ended_think=(first == ecfg.end_think_id),
            out_tokens=buf,
            out_len=jnp.ones((B,), jnp.int32),
        )

    # ------------------------------------------------------------- loop
    def reason(self, state: ServeState, *, max_tokens: int | None = None,
               use_monitor: bool = True,
               chunk_len: int | None = None) -> ServeState:
        """Run the reasoning loop until all sequences exit (EAT stop, natural
        </think>, or token budget).  Device-resident: each iteration is one
        jitted ``decode_chunk`` dispatch advancing up to ``chunk_len``
        tokens; the only host sync is the per-chunk ``active.any()``."""
        budget = jnp.asarray(max_tokens or self.ecfg.max_reasoning_tokens,
                             jnp.int32)
        # chunk_len <= 0 would make the device loop a no-op and spin the
        # host loop forever
        chunk = jnp.asarray(max(1, chunk_len or self.ecfg.chunk_len), jnp.int32)
        fn = self._chunk_mon if use_monitor else self._chunk_plain
        while True:
            state = fn(self.params, state, budget, chunk)
            if not bool(state.active.any()):
                break
        return state

    def _reason_per_token(self, state: ServeState, *,
                          max_tokens: int | None = None,
                          use_monitor: bool = True) -> ServeState:
        """The pre-chunking host loop: one jitted dispatch per token plus two
        host syncs per iteration.  Kept verbatim as the baseline for
        ``benchmarks/engine_throughput.py``."""
        ecfg = self.ecfg
        budget = max_tokens or ecfg.max_reasoning_tokens
        while bool(state.active.any()) and int(state.n_reasoning.max()) < budget:
            state = self._decode_fn(self.params, state)
            if use_monitor:
                due = self.monitor.due(state.monitor, state.last_token)
                if bool((due & state.active).any()):
                    eat = self._probe_fn(self.params, state.cache, state.next_pos)
                    mon = self.monitor.update(state.monitor, eat, due, state.active)
                    state = state._replace(monitor=mon)
                else:
                    state = state._replace(
                        monitor=self.monitor.tick_no_eval(state.monitor, state.active)
                    )
                exits = state.monitor.stop_flag
            else:
                exits = jnp.zeros_like(state.active)
            over = state.n_reasoning >= budget
            state = state._replace(active=state.active & ~exits & ~state.ended_think & ~over)
        return state

    # ------------------------------------------------ continuous batching
    def _admit(self, state: ServeState, one: ServeState, slot: int) -> ServeState:
        """Recycle batch ``slot`` with the single-sequence state ``one``
        (one jitted dispatch; ``slot`` is a traced scalar, so admissions
        into different slots share the compilation)."""
        return self._admit_fn(state, one, jnp.asarray(slot, jnp.int32))

    def serve(self, prompts, prompt_len, rng, *, batch_size: int,
              max_tokens: int | None = None, use_monitor: bool = True,
              chunk_len: int | None = None, answer_len: int = 0) -> list[dict]:
        """Continuous-batching serving loop over N requests with
        ``batch_size`` slots.

        prompts: (N, S) LEFT-padded; prompt_len: (N,).  Sequences that exit
        early free their slot mid-flight: the result is harvested, the next
        queued prompt is prefilled (B=1) and merged into the slot, and the
        chunked decode resumes with the batch still full.  Returns one dict
        per request (in request order): ``reasoning_tokens``,
        ``n_reasoning``, ``ended_think``, and — when ``answer_len`` > 0 —
        the greedy forced-answer ``answer_tokens`` produced from the
        sequence's cache before its slot was recycled.
        """
        prompts = jnp.asarray(prompts)
        prompt_len = jnp.asarray(prompt_len)
        n_req = prompts.shape[0]
        B = min(batch_size, n_req)
        budget = jnp.asarray(max_tokens or self.ecfg.max_reasoning_tokens,
                             jnp.int32)
        chunk = jnp.asarray(max(1, chunk_len or self.ecfg.chunk_len), jnp.int32)
        fn = self._chunk_mon if use_monitor else self._chunk_plain

        queue = deque(range(B, n_req))
        rng, sub = jax.random.split(rng)
        state = self.start(prompts[:B], prompt_len[:B], sub)
        slot_req: list[int | None] = list(range(B))
        results: list[Optional[dict]] = [None] * n_req

        def _check_capacity(when: str):
            # cur advances one shared slot per batch-wide decode step and
            # never rewinds; a wrap would silently overwrite live KV rows
            used = int(state.cache["cur"])
            if used + int(budget) > self.ecfg.capacity:
                raise RuntimeError(
                    f"EngineConfig.capacity={self.ecfg.capacity} cannot hold "
                    f"{when}: {used} slots committed + up to {int(budget)} "
                    f"decode steps would wrap the cache ring. Size capacity "
                    f"to the batch-lifetime token count "
                    f"(~prompt_width + ceil(n_requests / batch_size) * budget)."
                )

        _check_capacity("the initial batch")

        while any(r is not None for r in slot_req):
            if bool(state.active.any()):
                state = fn(self.params, state, budget, chunk)
            active_np = np.asarray(state.active)
            done = [s for s, r in enumerate(slot_req)
                    if r is not None and not active_np[s]]
            if not done:
                continue
            # harvest results (answers roll out from the still-intact cache
            # rows) BEFORE any slot is overwritten by an admission
            ans = None
            if answer_len:
                toks, _ = self.force_answer(state, answer_len, greedy=True)
                ans = np.asarray(toks)
            out_tokens = np.asarray(state.out_tokens)
            out_len = np.asarray(state.out_len)
            n_reasoning = np.asarray(state.n_reasoning)
            ended = np.asarray(state.ended_think)
            for s in done:
                r = slot_req[s]
                rec = {
                    "request": r,
                    "reasoning_tokens": out_tokens[s, :out_len[s]].copy(),
                    "n_reasoning": int(n_reasoning[s]),
                    "ended_think": bool(ended[s]),
                }
                if ans is not None:
                    rec["answer_tokens"] = ans[s].copy()
                results[r] = rec
                slot_req[s] = None
            for s in done:
                if not queue:
                    continue
                _check_capacity("another admission")
                r = queue.popleft()
                rng, sub = jax.random.split(rng)
                one = self.start(prompts[r:r + 1], prompt_len[r:r + 1], sub)
                state = self._admit(state, one, s)
                slot_req[s] = r
        return results

    # ------------------------------------------------------------- answers
    def force_answer(self, state: ServeState, n_tokens: int, rng=None,
                     *, greedy: bool = False):
        """GenTillEoS(Q, <think>, R, </think>; theta) — Eq. (10)/Alg. 1 line 11.
        Returns (tokens (B,n), logprobs (B,n))."""
        rng = rng if rng is not None else state.rng
        return self._rollout_fn(
            self.params, state.cache, state.next_pos, state.last_token, rng,
            n=n_tokens, greedy=greedy,
        )

    def rollout_answers(self, state: ServeState, k: int, n_tokens: int, rng):
        """K independent forced rollouts (for Pass@1 / #UA@K).  Returns
        tokens (K, B, n)."""
        rngs = jax.random.split(rng, k)
        outs = [self._rollout_fn(self.params, state.cache, state.next_pos,
                                 state.last_token, r, n=n_tokens)[0]
                for r in rngs]
        return jnp.stack(outs)

    def eval_eat_now(self, state: ServeState) -> jax.Array:
        return self._probe_fn(self.params, state.cache, state.next_pos)

    # ------------------------------------------------------------- tracing
    def reason_with_trace(
        self, state: ServeState, *, max_tokens: int, rollout_k: int = 0,
        rollout_len: int = 8, answer_extract: Optional[Callable] = None,
        confidence_len: int = 0,
    ) -> tuple[ServeState, list[dict]]:
        """Generate one long chain; at every due point record EAT (and
        optionally K rollout answers + confidence).  The offline evaluation
        protocol of App. H — no early exit is taken.

        Reuses the device-resident chunk step with ``chunk_len`` matched to
        the evaluation schedule (1 for the paragraph schedule — a due point
        can fall on any token — ``every_n`` for the fixed stride), so the
        per-evaluation host hooks below still run between chunks."""
        trace: list[dict] = []
        rng = state.rng
        newline_sched = self.monitor.schedule == "newline"
        chunk = jnp.asarray(1 if newline_sched else self.monitor.every_n,
                            jnp.int32)
        budget = jnp.asarray(max_tokens, jnp.int32)
        while bool(state.active.any()):
            prev_n = state.n_reasoning
            state = self._chunk_plain(self.params, state, budget, chunk)
            if newline_sched:
                due = state.last_token == self.monitor.newline_id
            else:
                due = jnp.ones_like(state.active)
            # mask by "emitted a token this chunk", not post-chunk active:
            # the chunk latches active=False in the same device step that
            # reaches the budget, but the budget-th token's evaluation point
            # still belongs in the trace (App. H records it)
            emitted = state.n_reasoning > prev_n
            due = due & emitted
            if bool(due.any()):
                rec: dict = {
                    "n_tokens": np.asarray(state.n_reasoning),
                    "due": np.asarray(due),
                    "eat": np.asarray(self.eval_eat_now(state)),
                }
                if rollout_k:
                    rng, sub = jax.random.split(rng)
                    rolls = self.rollout_answers(state, rollout_k, rollout_len, sub)
                    rec["rollouts"] = np.asarray(rolls)
                    if answer_extract is not None:
                        rec["answers"] = np.stack(
                            [answer_extract(np.asarray(rolls[i])) for i in range(rollout_k)]
                        )
                if confidence_len:
                    _, lps = self.force_answer(state, confidence_len, greedy=True)
                    rec["confidence"] = np.asarray(jnp.exp(lps.mean(-1)))
                mon = self.monitor.update(state.monitor, jnp.asarray(rec["eat"]),
                                          due, emitted)
                state = state._replace(monitor=mon)
                rec["ema_var"] = np.asarray(
                    self.monitor.stopper.debiased_var(mon.stop_state)
                )
                trace.append(rec)
        return state, trace
