"""Reasoning-serving facade over the layered serving stack.

The engine is now a thin orchestration layer; the real machinery lives in
three modules (DESIGN.md §4.4 + this PR's refactor):

  * ``serving/request.py``   — per-request lifecycle state machine
    (QUEUED -> PREFILLING -> DECODING -> EXITED/EXHAUSTED) carrying the EAT
    trace and exit-reason metadata,
  * ``serving/scheduler.py`` — slot allocation + FIFO admission policy for
    continuous batching (pure host Python, no jax),
  * ``serving/executor.py``  — every jitted device program (prefill,
    chunked decode with the inlined probe/monitor, admit, rollout, probe),
    built with explicit shardings from ``serve_state_pspecs`` /
    ``cache_pspecs`` and with the ServeState/cache DONATED so chunked
    decode updates the KV cache in place instead of re-allocating it.

``ReasoningEngine`` keeps the pre-refactor API (``start`` / ``reason`` /
``serve`` / ``force_answer`` / ``reason_with_trace`` ...) so examples,
benchmarks, and tests are untouched.  With a mesh on ``model.ctx`` the same
calls run data-parallel over batch rows and tensor-parallel over heads —
``tests/test_mesh_serve.py`` pins 8-way simulated-mesh ``serve()`` to the
single-device token stream.

Donation contract (inherited from the executor): ``reason()``, ``serve()``
and ``_admit()`` consume the ServeState they are handed — continue from the
returned state; the passed-in one is dead.

Per-sequence adaptivity in a batched TPU loop: exited sequences stay in
their slots with ``active=False`` — their sampled tokens are replaced by
PAD, their monitor state freezes, and cache writes become don't-cares
(nothing reads a finished sequence's future slots).

The same machinery provides the paper's evaluation harness:
``reason_with_trace`` generates one long chain and records, at every
evaluation point, EAT / confidence / forced-rollout answers — the offline
"simulated early exiting" protocol of App. H.
"""
from __future__ import annotations

import dataclasses
import time
from types import SimpleNamespace
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.eat import ProbeSpec
from repro.core.monitor import ReasoningMonitor
from repro.models.model import Model
from repro.serving.cache import (
    CacheConfig,
    alloc_cache,
    alloc_paged_template,
    page_align,
)
from repro.serving.executor import (
    Executor,
    ProxyExecutor,
    ServeState,
    positions_for,
)
from repro.serving.proxy import ProxyConfig, ProxyTier
from repro.serving.request import Request
from repro.serving.sampler import SamplerConfig, sample
from repro.serving.scheduler import (
    PageAllocator,
    SlotScheduler,
    pools_can_admit,
)

__all__ = ["CacheConfig", "EngineConfig", "ProxyConfig", "ReasoningEngine",
           "ServeState"]


@dataclasses.dataclass
class EngineConfig:
    max_reasoning_tokens: int = 1024
    capacity: int = 2048                 # cache slots (logical, when paged)
    pad_id: int = 0
    end_think_id: int = 1
    newline_id: int = 2
    eos_id: int = 3
    chunk_len: int = 32                  # decode steps per jitted dispatch
    sampler: SamplerConfig = dataclasses.field(default_factory=SamplerConfig)
    # KV-cache backend for serve(): ring (dense, capacity is a batch-
    # lifetime bound) or paged (block pool, capacity is per-block
    # bookkeeping — docs/serving.md)
    cache: CacheConfig = dataclasses.field(default_factory=CacheConfig)


class ReasoningEngine:
    """The serving facade, in one of two monitor modes:

    * ``monitor="self"`` (default): white-box — the reasoning model is also
      the EAT monitor model; the probe runs inline in the decode chunk.
    * ``monitor="proxy"`` (``proxy=ProxyConfig(...)``): black-box — the
      generator decodes whole chunks with NO inline probe (its executor
      never builds a probe program; no generator logits feed the exit
      decision), and a second model shadows the emitted chunks through a
      ``ProxyExecutor``, supplying ``eat_trace``/``exit_step`` through the
      executor's ``retract`` program.  Same-params proxies reproduce
      self-EAT serving bit-for-bit under greedy sampling
      (tests/test_proxy_serve.py).
    """

    def __init__(self, model: Model, params, ecfg: EngineConfig,
                 monitor: ReasoningMonitor | None = None,
                 proxy: ProxyConfig | None = None):
        from repro.core.stopping import EATStopper

        # the decode-attention impl is an EngineConfig.cache knob
        # (--attn-impl): bake it into the model so every executor program —
        # chunk, probe, rollout, shadow — traces the same read path, and
        # pin the ring comparator's block size to the paged page size (the
        # per-impl paged==ring bit-exactness contract, docs/architecture.md)
        ccfg = ecfg.cache
        if (model.paged_attn_impl != ccfg.attn_impl
                or model.paged_attn_page != ccfg.page_size):
            model = dataclasses.replace(model,
                                        paged_attn_impl=ccfg.attn_impl,
                                        paged_attn_page=ccfg.page_size)
        self.model = model
        self.ecfg = ecfg
        if monitor is None:
            monitor = ReasoningMonitor(
                stopper=EATStopper(),
                probe=ProbeSpec((ecfg.end_think_id,)),
                newline_id=ecfg.newline_id,
            )
        self.monitor = monitor
        self.executor = Executor(model, params, ecfg, monitor)
        # place params on the mesh once so per-dispatch in_shardings never
        # re-transfer them (no-op on single device)
        self.params = self.executor.shard_params(params)
        self.proxy = proxy
        self.proxy_executor = None
        self.proxy_params = None
        if proxy is not None:
            if model.cfg.arch_type in ("ssm", "hybrid"):
                raise ValueError(
                    "monitor='proxy' needs a slot-addressed generator cache "
                    "to retract overshoot tokens; SSM/hybrid recurrences "
                    "cannot be rewound to the proxy's exit step."
                )
            pccfg = proxy.cache or ecfg.cache
            proxy_model = proxy.model
            if (proxy_model.paged_attn_impl != pccfg.attn_impl
                    or proxy_model.paged_attn_page != pccfg.page_size):
                proxy_model = dataclasses.replace(
                    proxy_model, paged_attn_impl=pccfg.attn_impl,
                    paged_attn_page=pccfg.page_size)
            self.proxy_executor = ProxyExecutor(proxy_model, proxy.params,
                                                ecfg, monitor)
            self.proxy_params = self.proxy_executor.shard_params(proxy.params)

    @property
    def monitor_mode(self) -> str:
        return "proxy" if self.proxy is not None else "self"

    def _across_tiers(self, tree):
        """Ferry per-row scalars between the generator's and the proxy's
        meshes (a host hop of a few KB, both directions; identity when the
        tiers share a ctx — the common case)."""
        if self.proxy_executor.ctx.mesh is self.executor.ctx.mesh:
            return tree
        return jax.tree_util.tree_map(np.asarray, tree)

    def _positions(self, pos1d):
        return positions_for(self.model.cfg, pos1d)

    # engine internals the benchmarks/tests poke at, now delegated
    @property
    def _decode_fn(self):
        return self.executor.decode_step

    # ------------------------------------------------------------- prefill
    def start(self, prompts: jax.Array, prompt_len: jax.Array, rng,
              *, frames=None, image_embeds=None,
              capacity: int | None = None) -> ServeState:
        """prompts: (B, S) LEFT-padded token ids; prompt_len: (B,).

        Positions are 0..len-1 per sequence (pad slots get -1 = masked).
        ``capacity`` overrides ``EngineConfig.capacity`` (the paged serve
        path prefills into a prompt-sized dense cache and packs it into the
        page pool afterwards).
        """
        model, ecfg = self.model, self.ecfg
        B, S = prompts.shape
        pad = S - prompt_len                                # (B,)
        pos1d = jnp.arange(S, dtype=jnp.int32)[None, :] - pad[:, None]
        pos1d = jnp.where(pos1d >= 0, pos1d, -1)
        n_img = 0
        if image_embeds is not None:
            n_img = image_embeds.shape[1]
            img_pos = jnp.broadcast_to(
                jnp.arange(n_img, dtype=jnp.int32)[None], (B, n_img)
            )
            pos1d = jnp.concatenate([img_pos, jnp.where(pos1d >= 0, pos1d + n_img, -1)], 1)
        cache = alloc_cache(model.cfg, B, capacity or ecfg.capacity)
        hidden, cache = self.executor.prefill(
            self.params, prompts, self._positions(pos1d), pos1d, cache,
            frames=frames, image_embeds=image_embeds,
        )
        next_pos = prompt_len + n_img
        logits_last = self.model.logits(self.params, hidden[:, -1:])[:, 0]
        rng, sub = jax.random.split(rng)
        first = sample(sub, logits_last, model.cfg.vocab, ecfg.sampler)
        buf = jnp.full((B, ecfg.max_reasoning_tokens + 8), ecfg.pad_id, jnp.int32)
        buf = buf.at[:, 0].set(first)
        mon = self.monitor.init(B)
        return ServeState(
            cache=cache,
            rng=rng,
            active=jnp.ones((B,), bool),
            next_pos=next_pos.astype(jnp.int32),
            last_token=first,
            n_reasoning=jnp.ones((B,), jnp.int32),
            monitor=mon,
            ended_think=(first == ecfg.end_think_id),
            out_tokens=buf,
            out_len=jnp.ones((B,), jnp.int32),
        )

    # ------------------------------------------------------------- loop
    def reason(self, state: ServeState, *, max_tokens: int | None = None,
               use_monitor: bool = True,
               chunk_len: int | None = None) -> ServeState:
        """Run the reasoning loop until all sequences exit (EAT stop, natural
        </think>, or token budget).  Device-resident: each iteration is one
        jitted ``decode_chunk`` dispatch advancing up to ``chunk_len``
        tokens; the only host sync is the per-chunk ``active.any()``.
        CONSUMES ``state`` (the chunk program donates its buffers)."""
        if use_monitor and self.proxy is not None:
            raise ValueError(
                "monitor='proxy' runs through serve() (the proxy tier must "
                "prefill the prompts the scheduler admits — a bare "
                "ServeState does not carry them); use serve(), or pass "
                "use_monitor=False for an unmonitored reason()."
            )
        budget = jnp.asarray(max_tokens or self.ecfg.max_reasoning_tokens,
                             jnp.int32)
        # chunk_len <= 0 would make the device loop a no-op and spin the
        # host loop forever
        chunk = jnp.asarray(max(1, chunk_len or self.ecfg.chunk_len), jnp.int32)
        while True:
            state = self.executor.decode_chunk(self.params, state, budget,
                                               chunk, use_monitor=use_monitor)
            if not bool(state.active.any()):
                break
        return state

    def _reason_per_token(self, state: ServeState, *,
                          max_tokens: int | None = None,
                          use_monitor: bool = True) -> ServeState:
        """The pre-chunking host loop: one jitted dispatch per token plus two
        host syncs per iteration.  Kept verbatim as the baseline for
        ``benchmarks/engine_throughput.py``."""
        ecfg = self.ecfg
        budget = max_tokens or ecfg.max_reasoning_tokens
        while bool(state.active.any()) and int(state.n_reasoning.max()) < budget:
            state = self.executor.decode_step(self.params, state)
            if use_monitor:
                due = self.monitor.due(state.monitor, state.last_token)
                if bool((due & state.active).any()):
                    eat = self.executor.probe(self.params, state.cache,
                                              state.next_pos)
                    mon = self.monitor.update(state.monitor, eat, due, state.active)
                    state = state._replace(monitor=mon)
                else:
                    state = state._replace(
                        monitor=self.monitor.tick_no_eval(state.monitor, state.active)
                    )
                exits = state.monitor.stop_flag
            else:
                exits = jnp.zeros_like(state.active)
            over = state.n_reasoning >= budget
            state = state._replace(active=state.active & ~exits & ~state.ended_think & ~over)
        return state

    # ------------------------------------------------ continuous batching
    def _admit(self, state: ServeState, one: ServeState, slot: int) -> ServeState:
        """Recycle batch ``slot`` with the single-sequence state ``one``
        (one jitted dispatch; ``slot`` is a traced scalar, so admissions
        into different slots share the compilation).  CONSUMES ``state``."""
        return self.executor.admit(state, one, slot)

    def _serve_setup(self, prompts, prompt_len, rng, *, batch_size: int,
                     max_tokens: int | None, use_monitor: bool,
                     chunk_len: int | None,
                     overlap: bool = False) -> SimpleNamespace:
        """Shared front half of both serve loops (sync below, overlapped in
        ``serving.pipeline``): parse the request list, build the scheduler /
        page allocator / proxy tier, prefill + pack the initial cohort, and
        run the setup-time capacity checks.  Returns the namespace the loop
        bodies consume; ``cur0`` is the post-prefill ring pointer (already
        synced by the capacity check — the overlapped loop seeds its host
        mirror from it instead of re-syncing).  ``overlap`` widens the
        auto-sized page pool by one row allotment: the pipeline parks a
        harvested row's pages on the in-flight fence for one boundary, so
        a slot's old and new occupant briefly double-book its footprint."""
        prompts_np = np.asarray(prompts)
        plen_np = np.asarray(prompt_len)
        n_req = prompts_np.shape[0]
        S = prompts_np.shape[1]
        B = min(batch_size, n_req)
        budget = int(max_tokens or self.ecfg.max_reasoning_tokens)
        budget_dev = jnp.asarray(budget, jnp.int32)
        chunk_py = max(1, chunk_len or self.ecfg.chunk_len)
        chunk = jnp.asarray(chunk_py, jnp.int32)

        t0 = time.perf_counter()
        requests = [
            Request(rid=i, prompt=prompts_np[i], prompt_len=int(plen_np[i]),
                    submitted_at=t0)
            for i in range(n_req)
        ]
        sched = SlotScheduler(requests, B, capacity=self.ecfg.capacity,
                              budget=budget)

        # ---- cache backend (docs/serving.md): the paged path keeps the
        # ring's logical addressing but backs it with a page pool, so the
        # host loop additionally (a) maps pages for every slot range a
        # dispatch may write, (b) pushes the allocator's table before each
        # dispatch, (c) frees a request's pages at harvest
        ccfg = self.ecfg.cache
        paged = ccfg.kind == "paged"
        alloc = None
        C_pre = None
        probe_m = len(self.monitor.probe)
        if paged:
            ps = ccfg.page_size
            C_log = page_align(self.ecfg.capacity, ps)
            n_blocks = C_log // ps
            num_pages = ccfg.num_pages or (
                B * n_blocks + 1 + (n_blocks if overlap else 0))
            alloc = PageAllocator(num_pages, ps, n_blocks, B)
            C_pre = page_align(S, ps)      # prompt-sized prefill capacity

        # ---- proxy tier (monitor="proxy"): the generator chunk runs with
        # its inline monitor OFF — the black-box contract — and the proxy
        # shadows each chunk, feeding exits back through retract
        proxy_mode = use_monitor and self.proxy is not None
        ptier = None
        self._ptier = None       # kept for post-serve stats (tests/benches)
        if proxy_mode:
            ptier = self._ptier = ProxyTier(
                self.proxy_executor, self.proxy_params, self.ecfg,
                self.monitor, self.proxy.cache or ccfg,
                self.proxy.capacity or self.ecfg.capacity, budget,
            )
        gen_monitor = use_monitor and not proxy_mode

        cohort = sched.start_batch()
        rng, sub = jax.random.split(rng)
        state = self.start(jnp.asarray(prompts_np[:B]),
                           jnp.asarray(plen_np[:B]), sub,
                           capacity=C_pre if paged else None)
        if paged:
            for req in cohort:
                alloc.ensure(req.slot, 0, S - 1)       # the prompt pages
            template = alloc_paged_template(
                self.model.cfg, B, C_log, ps, num_pages, alloc=alloc,
                native=ccfg.attn_impl != "gather")
            state = state._replace(cache=self.executor.pack_paged(
                template, state.cache, alloc.table))
        if ptier is not None:
            ptier.start_batch(prompts_np[:B], plen_np[:B],
                              [req.slot for req in cohort])
        for req in cohort:
            req.begin_decode()
        cur0 = int(state.cache["cur"])
        sched.check_capacity(cur0, "the initial batch")
        if ptier is not None:
            ptier.check_capacity("the initial batch")

        # the generator only pays a probe tail when IT runs the probe; in
        # proxy mode that tail belongs to the proxy tier's pool
        gen_tail = 0 if proxy_mode else probe_m
        return SimpleNamespace(
            prompts_np=prompts_np, plen_np=plen_np, n_req=n_req, S=S, B=B,
            budget=budget, budget_dev=budget_dev, chunk_py=chunk_py,
            chunk=chunk, requests=requests, sched=sched, paged=paged,
            alloc=alloc, C_pre=C_pre, proxy_mode=proxy_mode, ptier=ptier,
            gen_monitor=gen_monitor, gen_tail=gen_tail, rng=rng, state=state,
            cur0=cur0,
        )

    def serve(self, prompts, prompt_len, rng, *, batch_size: int,
              max_tokens: int | None = None, use_monitor: bool = True,
              chunk_len: int | None = None, answer_len: int = 0,
              record_trace: bool = False, overlap: bool = False,
              pipeline_hooks=None) -> list[dict]:
        """Continuous-batching serving loop over N requests with
        ``batch_size`` slots.

        prompts: (N, S) LEFT-padded; prompt_len: (N,).  Each request runs
        the QUEUED -> PREFILLING -> DECODING -> EXITED/EXHAUSTED lifecycle
        (``serving.request``); the FIFO slot policy lives in
        ``serving.scheduler``; all device work is executor programs.
        Sequences that exit early free their slot mid-flight: the result is
        harvested, the next queued prompt is prefilled (B=1) and merged into
        the slot, and the chunked decode resumes with the batch still full.

        With ``EngineConfig.cache.kind == "paged"`` the KV store is the
        block-paged pool (docs/serving.md): an exiting request's pages are
        reclaimed at harvest and back the very next admission, and the
        token streams/exit steps/EAT trajectories are bit-identical to the
        ring path's.  Backpressure is admission-time only — an admission
        waits (rather than failing) while the pool is momentarily full,
        but the optimistic prompt+one-page admission rule means a pool
        undersized for the RESIDENT batch (below ~batch * (prompt + budget
        + probe) / page_size pages) can still exhaust mid-decode, which
        fails fast with a sizing hint rather than corrupting neighbours.

        In ``monitor="proxy"`` mode the same loop runs black-box: the
        generator chunk decodes unmonitored, the proxy tier shadows the
        emitted tokens (its own prefills/pages in lock-step with the
        scheduler), and the executor's ``retract`` reconciles each chunk —
        rewinding rows the proxy stopped mid-chunk and syncing the proxy's
        monitor state so harvest, traces, and exit reasons read identically
        to self-EAT.  Admissions gate on BOTH page pools
        (``scheduler.pools_can_admit``): an exhausted proxy pool defers
        admission independently of the generator pool.

        Returns one dict per request (in request order): the pre-refactor
        keys (``reasoning_tokens``, ``n_reasoning``, ``ended_think``, and —
        when ``answer_len`` > 0 — the greedy forced-answer
        ``answer_tokens``) plus the request metadata: ``exit_reason``
        (``eat`` / ``end_think`` / ``budget``), terminal ``status``,
        per-request ``latency_s``, and — with ``record_trace`` — the
        chunk-boundary ``eat_trace`` (n_reasoning, n_evals, ema_var)
        snapshots.

        With ``overlap=True`` the loop is the double-buffered pipeline of
        ``serving.pipeline``: chunk N+1 is dispatched before chunk N's
        boundary is harvested, admissions/page-table pushes move into the
        overlap window, and in proxy mode the shadow of chunk N runs
        concurrently with generator chunk N+1 (retract lands one boundary
        late — exit latency +≤1 chunk, token streams unchanged).  Under
        greedy sampling the results are bit-identical to ``overlap=False``
        (tests/test_async_serve.py); with temperature sampling the rng
        split schedule differs, so streams may diverge (still valid
        samples).  ``pipeline_hooks`` (a ``serving.pipeline.PipelineHooks``)
        is the test seam for forcing adversarial interleavings.
        """
        ss = self._serve_setup(prompts, prompt_len, rng,
                               batch_size=batch_size, max_tokens=max_tokens,
                               use_monitor=use_monitor, chunk_len=chunk_len,
                               overlap=overlap)
        if overlap:
            from repro.serving.pipeline import serve_overlapped
            try:
                return serve_overlapped(self, ss, answer_len=answer_len,
                                        record_trace=record_trace,
                                        hooks=pipeline_hooks)
            finally:
                if ss.ptier is not None:
                    # drop the proxy tier's device buffers; host-side
                    # allocator stats stay readable via ``_ptier``
                    ss.ptier.state = None
        # ---- synchronous loop (--overlap off): one host round trip per
        # chunk boundary.  The overlapped loop must stay bit-exact with
        # this body under greedy sampling — change them together.
        sched, state, rng = ss.sched, ss.state, ss.rng
        alloc, ptier, paged = ss.alloc, ss.ptier, ss.paged
        proxy_mode, gen_monitor = ss.proxy_mode, ss.gen_monitor
        S, budget_dev = ss.S, ss.budget_dev
        budget, chunk_py, chunk = ss.budget, ss.chunk_py, ss.chunk
        gen_tail, C_pre = ss.gen_tail, ss.C_pre

        def ensure_pages(span: int, *, clamp_to_budget: bool = False):
            """Occupied-slot pages for the next generator dispatch — the
            shared sizing rule lives in ``Executor.ensure_chunk_pages``."""
            return self.executor.ensure_chunk_pages(
                alloc, state, [s for s, _ in sched.bound()], span,
                tail=gen_tail, budget=budget if clamp_to_budget else None,
            )

        while sched.running:
            if bool(state.active.any()):
                if paged:
                    # a chunk writes <= chunk_len decode tokens (fewer for
                    # rows near their budget), each probe another
                    # len(probe) slots past the decode slot
                    state = ensure_pages(chunk_py + gen_tail,
                                         clamp_to_budget=True)
                # host copy BEFORE the dispatch: the chunk donates ``state``
                n_start = np.asarray(state.out_len) if proxy_mode else None
                state = self.executor.decode_chunk(
                    self.params, state, budget_dev, chunk,
                    use_monitor=gen_monitor,
                )
                if proxy_mode:
                    # shadow the chunk through the proxy, then reconcile:
                    # rewind overshoot rows to the proxy's exit step and
                    # sync its monitor into the state (executor.retract)
                    n_emitted = np.asarray(state.out_len) - n_start
                    ptier.begin_chunk(chunk_py,
                                      [s for s, _ in sched.bound()])
                    new_n, pmon = ptier.observe(
                        self._across_tiers(state.out_tokens), n_start,
                        n_emitted, chunk_py,
                    )
                    state = self.executor.retract(
                        state, self._across_tiers(new_n),
                        self._across_tiers(pmon),
                    )
            active_np = np.asarray(state.active)
            if record_trace:
                n_np = np.asarray(state.n_reasoning)
                ev_np = np.asarray(state.monitor.n_evals)
                var_np = np.asarray(
                    self.monitor.stopper.debiased_var(state.monitor.stop_state)
                )
                for s, req in sched.bound():
                    req.record_trace(n_np[s], ev_np[s], var_np[s])
            done = sched.finished_slots(active_np)
            if not done:
                continue
            # harvest results (answers roll out from the still-intact cache
            # rows) BEFORE any slot is overwritten by an admission
            ans = None
            if answer_len:
                if paged:
                    # a rollout writes </think> + answer_len slots past cur
                    state = ensure_pages(answer_len + 1)
                toks, _ = self.force_answer(state, answer_len, greedy=True)
                ans = np.asarray(toks)
            out_tokens = np.asarray(state.out_tokens)
            out_len = np.asarray(state.out_len)
            n_reasoning = np.asarray(state.n_reasoning)
            ended = np.asarray(state.ended_think)
            eat_stop = np.asarray(state.monitor.stop_flag)
            for s, req in done:
                sched.release(s)
                req.finish(
                    reasoning_tokens=out_tokens[s, :out_len[s]].copy(),
                    n_reasoning=int(n_reasoning[s]),
                    ended_think=bool(ended[s]),
                    eat_stop=bool(eat_stop[s]),
                    answer_tokens=ans[s].copy() if ans is not None else None,
                )
                if paged:
                    # reclaim the moment a request exits: these pages back
                    # the admissions below, in the same batch
                    alloc.free_row(s)
                if ptier is not None:
                    # the proxy's shadow pages are reclaimed in the same
                    # breath — a proxy-driven exit frees BOTH pools
                    ptier.free_row(s)
            # admission sweeps EVERY free slot, not just this round's
            # harvested ones: a paged admission deferred earlier (pool
            # momentarily full) left its slot empty, and the pages freed
            # just above are what let it proceed now.  (For the ring this
            # is identical to sweeping ``done``: a ring slot is only ever
            # left empty once the queue has drained.)
            for s in (s for s, r in enumerate(sched.slots) if r is None):
                if sched.pending == 0:
                    continue
                # refuse BEFORE popping the queue: a capacity failure must
                # leave the scheduler consistent (no stranded PREFILLING
                # request holding a slot).  The logical-ring wrap guard
                # applies to BOTH backends (paged keeps ring addressing);
                # the paged page check DEFERS instead of refusing — the
                # request stays queued until an exit frees enough pages.
                sched.check_capacity(int(state.cache["cur"]),
                                     "another admission")
                if ptier is not None:
                    ptier.check_capacity("another admission")
                # both pools must cover the prompt (all-or-nothing): an
                # exhausted proxy pool defers the admission exactly like an
                # exhausted generator pool — the request stays queued until
                # a harvest frees pages in whichever pool is short
                if not pools_can_admit(S, alloc,
                                       ptier.alloc if ptier else None):
                    for a in (alloc, ptier.alloc if ptier else None):
                        if a is not None and not a.can_admit(S):
                            a.deferrals += 1
                    continue
                nxt = sched.admit_next(s)
                rng, sub = jax.random.split(rng)
                one = self.start(jnp.asarray(nxt.prompt[None]),
                                 jnp.asarray([nxt.prompt_len]), sub,
                                 capacity=C_pre if paged else None)
                if paged:
                    row_table = alloc.admit_row(s, S,
                                                int(state.cache["cur"]))
                    state = self.executor.admit_paged(state, one, s,
                                                      row_table)
                else:
                    state = self._admit(state, one, s)
                if ptier is not None:
                    ptier.admit(s, nxt.prompt, nxt.prompt_len, S)
                nxt.begin_decode()
            if sched.pending and not sched.running:
                # every slot is empty yet the queue cannot drain — name the
                # pool that is actually too small to hold one request
                if paged and not alloc.can_admit(S):
                    raise RuntimeError(
                        f"paged KV cache cannot hold a single request: "
                        f"{alloc.free_pages} pages free with every slot "
                        f"empty, but a prompt needs "
                        f"{alloc.blocks_for(S) + 1} pages. "
                        f"Raise CacheConfig.num_pages."
                    )
                if ptier is not None and not ptier.can_admit(S):
                    raise RuntimeError(
                        f"proxy paged KV cache cannot hold a single "
                        f"request: {ptier.alloc.free_pages} pages free with "
                        f"every slot empty, but a prompt needs "
                        f"{ptier.alloc.blocks_for(S) + 1} pages. "
                        f"Raise ProxyConfig.cache.num_pages."
                    )
        if ptier is not None:
            # drop the proxy tier's device buffers (its KV cache/pool is
            # the tier's largest allocation); the host-side allocator
            # stats stay readable via ``_ptier`` for tests and benches
            ptier.state = None
        return [r.to_result() for r in ss.requests]

    # ------------------------------------------------------------- answers
    def force_answer(self, state: ServeState, n_tokens: int, rng=None,
                     *, greedy: bool = False):
        """GenTillEoS(Q, <think>, R, </think>; theta) — Eq. (10)/Alg. 1 line 11.
        Returns (tokens (B,n), logprobs (B,n))."""
        rng = rng if rng is not None else state.rng
        return self.executor.rollout(
            self.params, state.cache, state.next_pos, state.last_token, rng,
            n=n_tokens, greedy=greedy,
        )

    def rollout_answers(self, state: ServeState, k: int, n_tokens: int, rng):
        """K independent forced rollouts (for Pass@1 / #UA@K).  Returns
        tokens (K, B, n)."""
        rngs = jax.random.split(rng, k)
        outs = [self.executor.rollout(self.params, state.cache, state.next_pos,
                                      state.last_token, r, n=n_tokens)[0]
                for r in rngs]
        return jnp.stack(outs)

    def eval_eat_now(self, state: ServeState) -> jax.Array:
        return self.executor.probe(self.params, state.cache, state.next_pos)

    # ------------------------------------------------------------- tracing
    def reason_with_trace(
        self, state: ServeState, *, max_tokens: int, rollout_k: int = 0,
        rollout_len: int = 8, answer_extract: Optional[Callable] = None,
        confidence_len: int = 0,
    ) -> tuple[ServeState, list[dict]]:
        """Generate one long chain; at every due point record EAT (and
        optionally K rollout answers + confidence).  The offline evaluation
        protocol of App. H — no early exit is taken.

        Reuses the device-resident chunk step with ``chunk_len`` matched to
        the evaluation schedule (1 for the paragraph schedule — a due point
        can fall on any token — ``every_n`` for the fixed stride), so the
        per-evaluation host hooks below still run between chunks."""
        trace: list[dict] = []
        rng = state.rng
        newline_sched = self.monitor.schedule == "newline"
        chunk = jnp.asarray(1 if newline_sched else self.monitor.every_n,
                            jnp.int32)
        budget = jnp.asarray(max_tokens, jnp.int32)
        while bool(state.active.any()):
            # host copy BEFORE the chunk: the chunk donates ``state``, so a
            # live reference to its n_reasoning buffer would be invalidated
            prev_n = np.asarray(state.n_reasoning)
            state = self.executor.decode_chunk(self.params, state, budget,
                                               chunk, use_monitor=False)
            if newline_sched:
                due = state.last_token == self.monitor.newline_id
            else:
                due = jnp.ones_like(state.active)
            # mask by "emitted a token this chunk", not post-chunk active:
            # the chunk latches active=False in the same device step that
            # reaches the budget, but the budget-th token's evaluation point
            # still belongs in the trace (App. H records it)
            emitted = state.n_reasoning > prev_n
            due = due & emitted
            if bool(due.any()):
                rec: dict = {
                    "n_tokens": np.asarray(state.n_reasoning),
                    "due": np.asarray(due),
                    "eat": np.asarray(self.eval_eat_now(state)),
                }
                if rollout_k:
                    rng, sub = jax.random.split(rng)
                    rolls = self.rollout_answers(state, rollout_k, rollout_len, sub)
                    rec["rollouts"] = np.asarray(rolls)
                    if answer_extract is not None:
                        rec["answers"] = np.stack(
                            [answer_extract(np.asarray(rolls[i])) for i in range(rollout_k)]
                        )
                if confidence_len:
                    _, lps = self.force_answer(state, confidence_len, greedy=True)
                    rec["confidence"] = np.asarray(jnp.exp(lps.mean(-1)))
                mon = self.monitor.update(state.monitor, jnp.asarray(rec["eat"]),
                                          due, emitted)
                state = state._replace(monitor=mon)
                rec["ema_var"] = np.asarray(
                    self.monitor.stopper.debiased_var(mon.stop_state)
                )
                trace.append(rec)
        return state, trace
