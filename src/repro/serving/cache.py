"""Decode-state allocation: KV caches, MLA latent caches, SSM states.

Layout contract (consumed by ``models.transformer.forward_cached``):

  cache = {
    "layers": { <segment>: <stacked entries> },
    "pos":  (B, C) int32  — absolute position held in each slot, -1 = empty,
    "cur":  ()   int32    — committed length (ring: total tokens seen),
    ["enc_pos"]: (B, T)   — encoder positions (encdec only),
  }

Segments mirror the parameter stack segments:
  dense/vlm : {"seg":       {"k","v": (L,B,C,Hkv,hd)}}
  moe       : {"dense_seg": ..., "moe_seg": ...}
  mla (moe) : entries {"c": (L,B,C,r), "kr": (L,B,C,rope_d)}
  encdec    : {"dec_seg":   {"k","v", "ck","cv": (L,B,T,Hkv,hd)}}
  ssm       : {"seg":       {"ssm": (L,B,nh,N,hp), "conv": {...}}}
  hybrid    : {"ssm_seg": (G,n_per,B,...), "attn_seg": {"k","v": (G,B,C,H,hd)}}

Sliding-window configs use a ring buffer: capacity == window and slots are
``(cur + arange(m)) % capacity`` (see ``write_slots``); masking relies on the
explicit ``pos`` array, so ring order is irrelevant to attention.

Block-paged variant (``CacheConfig.kind="paged"``, docs/architecture.md):
same logical layout and ``pos``/``cur`` semantics, but the attention
entries become page POOLS — ``(L, num_pages, page_size, Hkv, hd)`` instead
of ``(L, B, C, Hkv, hd)`` — plus a ``page_table`` (B, NB) int32 mapping
each row's logical blocks to physical pages.  ``gather_pages`` reconstructs
the per-row logical view for attention; unmapped blocks read the reserved
trash page (entry 0), whose contents are always position-masked.  The
physical footprint is live tokens (page-granular), not batch-lifetime
capacity — the unlock for long continuous-batching queues.

Sharding (DESIGN.md §7): batch -> (pod,data); kv-heads -> model when
divisible, otherwise the capacity dim C -> model (GSPMD inserts the
partial-softmax collectives); MLA latent and SSM state follow the same rule
(C -> model for MLA; SSD heads -> model for SSM).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.ssm import ssm_dims
from repro.models.transformer import (  # noqa: F401  (re-exports)
    gather_pages,
    scatter_pages,
    write_slots,
)
from repro.sharding.partition import ShardCtx


#: physical page id reserved as the trash page — never handed out by the
#: allocator; unmapped page-table entries point here, so stray writes from
#: rows without a mapping land in it and every read of it is position-masked
PAGE_TRASH = 0

#: cache leaf names stored as page pools in a paged cache (attention K/V and
#: the MLA latent/rope entries — everything with a capacity axis)
POOLED_LEAVES = ("k", "v", "c", "kr")


def page_align(n_slots: int, page_size: int) -> int:
    """Round a slot count up to a whole number of pages."""
    return -(-n_slots // page_size) * page_size


@dataclasses.dataclass
class CacheConfig:
    """KV-cache backend selection for the serving stack.

    ``kind="ring"`` is the classic dense ring buffer: ``capacity`` logical
    slots are physically allocated per batch row, so capacity is a
    batch-lifetime bound (``SlotScheduler.required_capacity``).

    ``kind="paged"`` keeps the same logical addressing but backs it with a
    block-paged pool (``num_pages`` pages of ``page_size`` slots each,
    shared by all rows): physical memory is bounded by LIVE tokens, a
    request's pages return to the free list the moment it exits, and
    admission becomes per-block bookkeeping (``scheduler.PageAllocator``).
    See docs/architecture.md — the paged path reproduces the ring path's
    token streams, exit steps, and EAT trajectories exactly.
    """

    kind: str = "ring"                 # "ring" | "paged"
    page_size: int = 16                # logical slots per physical page
    # 0 = auto: ring-equivalent pool (batch * capacity/page_size data pages
    # + the trash page) — never refuses an admission the ring would accept
    num_pages: int = 0
    # decode/probe attention implementation (kernels/paged_attention):
    #   "gather"              — classic: the paged path materializes the
    #                           gathered logical view before dense attention
    #   "auto" | "xla" | "pallas" — page-native: K/V are read straight off
    #                           the page pools through the compacted
    #                           mapped-page list, so per-token decode cost is
    #                           O(mapped pages) instead of O(logical
    #                           capacity); the ring backend runs the same
    #                           block-sequential algorithm, keeping
    #                           paged == ring bit-exact per impl
    #                           (docs/serving.md §--attn-impl)
    attn_impl: str = "gather"

    def __post_init__(self):
        if self.kind not in ("ring", "paged"):
            raise ValueError(f"CacheConfig.kind must be 'ring' or 'paged', "
                             f"got {self.kind!r}")
        if self.page_size < 1:
            raise ValueError("CacheConfig.page_size must be >= 1")
        if self.attn_impl not in ("gather", "auto", "xla", "pallas"):
            raise ValueError(f"CacheConfig.attn_impl must be one of "
                             f"gather/auto/xla/pallas, got {self.attn_impl!r}")


def _attn_entry(cfg: ModelConfig, lead: tuple[int, ...], B: int, C: int, dtype):
    hd = cfg.resolved_head_dim
    if cfg.mla is not None:
        m = cfg.mla
        return {
            "c": jnp.zeros(lead + (B, C, m.kv_lora_rank), dtype),
            "kr": jnp.zeros(lead + (B, C, m.qk_rope_head_dim), dtype),
        }
    return {
        "k": jnp.zeros(lead + (B, C, cfg.n_kv_heads, hd), dtype),
        "v": jnp.zeros(lead + (B, C, cfg.n_kv_heads, hd), dtype),
    }


def _ssm_entry(cfg: ModelConfig, lead: tuple[int, ...], B: int, dtype):
    dm = ssm_dims(cfg)
    gn = dm.n_groups * dm.d_state
    return {
        "ssm": jnp.zeros(lead + (B, dm.n_heads, dm.d_state, dm.head_dim), jnp.float32),
        "conv": {
            "x": jnp.zeros(lead + (B, dm.conv_width - 1, dm.d_inner), dtype),
            "bc": jnp.zeros(lead + (B, dm.conv_width - 1, 2 * gn), dtype),
        },
    }


def alloc_cache(cfg: ModelConfig, batch: int, capacity: int, dtype=None) -> dict:
    """Allocate an empty cache with ``capacity`` kv slots per sequence."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    B, C = batch, capacity
    cache: dict = {
        "pos": jnp.full((B, C), -1, jnp.int32),
        "cur": jnp.zeros((), jnp.int32),
    }
    if cfg.arch_type in ("dense", "vlm"):
        cache["layers"] = {"seg": _attn_entry(cfg, (cfg.n_layers,), B, C, dtype)}
    elif cfg.arch_type == "moe":
        fk = cfg.moe.first_k_dense
        layers = {}
        if fk:
            layers["dense_seg"] = _attn_entry(cfg, (fk,), B, C, dtype)
        layers["moe_seg"] = _attn_entry(cfg, (cfg.n_layers - fk,), B, C, dtype)
        cache["layers"] = layers
    elif cfg.arch_type == "encdec":
        T = cfg.encoder_len
        entry = _attn_entry(cfg, (cfg.n_layers,), B, C, dtype)
        hd = cfg.resolved_head_dim
        entry["ck"] = jnp.zeros((cfg.n_layers, B, T, cfg.n_kv_heads, hd), dtype)
        entry["cv"] = jnp.zeros((cfg.n_layers, B, T, cfg.n_kv_heads, hd), dtype)
        cache["layers"] = {"dec_seg": entry}
        cache["enc_pos"] = jnp.zeros((B, T), jnp.int32)
    elif cfg.arch_type == "ssm":
        cache["layers"] = {"seg": _ssm_entry(cfg, (cfg.n_layers,), B, dtype)}
    elif cfg.arch_type == "hybrid":
        pat = cfg.hybrid_pattern
        n_per = sum(1 for k in pat if k == "ssm")
        G = cfg.n_layers // len(pat)
        cache["layers"] = {
            "ssm_seg": _ssm_entry(cfg, (G, n_per), B, dtype),
            "attn_seg": _attn_entry(cfg, (G,), B, C, dtype),
        }
    else:
        raise ValueError(cfg.arch_type)
    return cache


# ------------------------------------------------------------ paged variant


def _pooled_attn_entry(cfg: ModelConfig, lead: tuple[int, ...],
                       num_pages: int, page_size: int, dtype):
    """Page-pool form of ``_attn_entry``: (B, C) -> (num_pages, page_size)."""
    hd = cfg.resolved_head_dim
    if cfg.mla is not None:
        m = cfg.mla
        return {
            "c": jnp.zeros(lead + (num_pages, page_size, m.kv_lora_rank), dtype),
            "kr": jnp.zeros(lead + (num_pages, page_size, m.qk_rope_head_dim), dtype),
        }
    return {
        "k": jnp.zeros(lead + (num_pages, page_size, cfg.n_kv_heads, hd), dtype),
        "v": jnp.zeros(lead + (num_pages, page_size, cfg.n_kv_heads, hd), dtype),
    }


def blocks_arrays(pages, logical, counts) -> dict:
    """Device form of the allocator's compacted mapped-page list (the
    page-native attention's read index; ``PageAllocator.block_buckets``).
    pages/logical: (B, NBK) int32 — physical page and logical block per
    mapped rank, trash/0-padded past ``counts`` (B,); padding ranks read
    the trash page with every position masked, so they are exact identity
    steps in the block scan (kernels/paged_attention/ref.py)."""
    return {
        "pages": jnp.asarray(pages, jnp.int32),
        "logical": jnp.asarray(logical, jnp.int32),
        "count": jnp.asarray(counts, jnp.int32),
    }


def alloc_paged_template(cfg: ModelConfig, batch: int, capacity: int,
                         page_size: int, num_pages: int, *,
                         alloc=None, native: bool = False,
                         dtype=None) -> dict:
    """The pack_paged_cache template every paged serve start builds: an
    empty paged cache, plus — in page-native mode — the allocator's
    current compacted mapped-page buckets baked in (``alloc`` is a
    ``scheduler.PageAllocator``; later refreshes ride
    ``Executor.put_page_table``).  THE single definition of the blocks
    baking ritual, shared by the engine, the proxy tier, and the
    benchmarks — so the read-index format cannot fork between them."""
    if not native:
        return alloc_paged_cache(cfg, batch, capacity, page_size, num_pages,
                                 dtype)
    width = alloc.bucket_width()
    cache = alloc_paged_cache(cfg, batch, capacity, page_size, num_pages,
                              dtype, block_bucket=width)
    cache["blocks"] = blocks_arrays(*alloc.block_buckets(width))
    return cache


def alloc_paged_cache(cfg: ModelConfig, batch: int, capacity: int,
                      page_size: int, num_pages: int, dtype=None,
                      block_bucket: int = 0) -> dict:
    """Allocate an empty block-paged cache.

    ``capacity`` is the LOGICAL ring length (must be a page multiple); the
    physical K/V footprint is ``num_pages * page_size`` slots shared by all
    ``batch`` rows through the page table (initialised all-trash).  Leaves
    without a capacity axis (SSM/conv states, encdec cross K/V) stay dense —
    they are per-row recurrent state, not slot-addressed storage.

    ``block_bucket`` > 0 adds the ``blocks`` arrays (width ``block_bucket``,
    all-trash) that the page-native ``attn_impl`` modes read; the engine
    refreshes them from the allocator before every dispatch.
    """
    dtype = dtype or jnp.dtype(cfg.dtype)
    if capacity % page_size:
        raise ValueError(f"paged capacity {capacity} must be a multiple of "
                         f"page_size {page_size}")
    if num_pages < 2:
        raise ValueError("num_pages must be >= 2 (page 0 is the trash page)")
    B, NB = batch, capacity // page_size
    cache: dict = {
        "pos": jnp.full((B, capacity), -1, jnp.int32),
        "cur": jnp.zeros((), jnp.int32),
        "page_table": jnp.full((B, NB), PAGE_TRASH, jnp.int32),
    }
    if block_bucket:
        z = np.zeros((B, block_bucket), np.int32)
        cache["blocks"] = blocks_arrays(z, z, np.zeros((B,), np.int32))
    if cfg.arch_type in ("dense", "vlm"):
        cache["layers"] = {
            "seg": _pooled_attn_entry(cfg, (cfg.n_layers,), num_pages, page_size, dtype)
        }
    elif cfg.arch_type == "moe":
        fk = cfg.moe.first_k_dense
        layers = {}
        if fk:
            layers["dense_seg"] = _pooled_attn_entry(cfg, (fk,), num_pages, page_size, dtype)
        layers["moe_seg"] = _pooled_attn_entry(
            cfg, (cfg.n_layers - fk,), num_pages, page_size, dtype)
        cache["layers"] = layers
    elif cfg.arch_type == "encdec":
        T = cfg.encoder_len
        entry = _pooled_attn_entry(cfg, (cfg.n_layers,), num_pages, page_size, dtype)
        hd = cfg.resolved_head_dim
        entry["ck"] = jnp.zeros((cfg.n_layers, B, T, cfg.n_kv_heads, hd), dtype)
        entry["cv"] = jnp.zeros((cfg.n_layers, B, T, cfg.n_kv_heads, hd), dtype)
        cache["layers"] = {"dec_seg": entry}
        cache["enc_pos"] = jnp.zeros((B, T), jnp.int32)
    elif cfg.arch_type == "hybrid":
        pat = cfg.hybrid_pattern
        n_per = sum(1 for k in pat if k == "ssm")
        G = cfg.n_layers // len(pat)
        cache["layers"] = {
            "ssm_seg": _ssm_entry(cfg, (G, n_per), B, dtype),
            "attn_seg": _pooled_attn_entry(cfg, (G,), num_pages, page_size, dtype),
        }
    elif cfg.arch_type == "ssm":
        raise ValueError("arch 'ssm' has no KV capacity axis to page — use "
                         "the ring cache (its state is O(1) per row already)")
    else:
        raise ValueError(cfg.arch_type)
    return cache


# pool rank of a single-layer pooled entry (page, page_size, ...tail); any
# extra leading axes are layer stacks
_POOL_NDIM = {"k": 4, "v": 4, "c": 3, "kr": 3}


def _is_pooled(path: str) -> bool:
    return path.startswith("layers/") and path.split("/")[-1] in POOLED_LEAVES


def pack_paged_cache(paged: dict, dense: dict, table) -> dict:
    """Scatter a freshly prefilled DENSE cache into an empty paged cache —
    the serve()-start conversion (one jitted dispatch, ``paged`` donated).

    ``dense`` has prefill capacity C_pre (a page multiple, C_pre <= logical
    capacity); ``table`` is the allocator's (B, NB) page table with the
    prompt blocks mapped.  Blocks of ``dense`` beyond a row's mapped prompt
    scatter into the trash page (zeros over garbage — a don't-care).
    Non-pooled leaves (SSM/conv state, cross K/V, enc_pos) copy wholesale.
    """
    from repro.utils.treeutil import tree_flatten_with_paths

    NB = table.shape[1]
    ps = paged["pos"].shape[1] // NB
    C_pre = dense["pos"].shape[1]
    nbp = C_pre // ps
    flat_d = dict(tree_flatten_with_paths(dense))
    merged = []
    for path, leaf in tree_flatten_with_paths(paged):
        name = path.split("/")[-1]
        if path.startswith("blocks/"):
            # the compacted page list is host-owned: the engine bakes the
            # allocator's current buckets into the template before packing
            # and refreshes them before every dispatch (put_page_table)
            merged.append(leaf)
        elif name == "page_table":
            merged.append(jnp.asarray(table, jnp.int32))
        elif name == "pos":
            merged.append(leaf.at[:, :C_pre].set(dense["pos"]))
        elif name == "cur":
            merged.append(dense["cur"])
        elif _is_pooled(path):
            src = flat_d[path]
            lead = leaf.ndim - _POOL_NDIM[name]
            B = src.shape[lead]
            tail = src.shape[lead + 2:]
            srcb = src.reshape(src.shape[:lead] + (B, nbp, ps) + tail)
            idx = (slice(None),) * lead + (table[:, :nbp],)
            merged.append(leaf.at[idx].set(srcb.astype(leaf.dtype)))
        else:
            merged.append(flat_d[path])
    treedef = jax.tree_util.tree_structure(paged)
    return jax.tree_util.tree_unflatten(treedef, merged)


def merge_paged_row(cache: dict, one: dict, row, row_table) -> dict:
    """Paged-cache slot admission: write the single-sequence DENSE cache
    ``one`` (batch=1, prefill capacity C_pre) into batch row ``row``.

    The paged analog of ``merge_cache_row``: the row's page-table entry is
    replaced by ``row_table`` (the allocator's fresh mapping: prompt blocks
    + the current decode block), the prompt K/V scatter into those pages,
    the row's logical ``pos`` is replaced (tail stays -1), and ``cur``
    advances to ``max(cur, one_cur)`` — identical ring semantics, so the
    admitted row's token stream matches the ring path's bit-for-bit.
    """
    from repro.utils.treeutil import tree_flatten_with_paths

    C = cache["pos"].shape[1]
    NB = cache["page_table"].shape[1]
    ps = C // NB
    C_pre = one["pos"].shape[1]
    nbp = C_pre // ps
    flat_one = dict(tree_flatten_with_paths(one))
    merged = []
    for path, leaf in tree_flatten_with_paths(cache):
        name = path.split("/")[-1]
        if path.startswith("blocks/"):
            # host-owned (see pack_paged_cache): the admitting engine pushes
            # the allocator's fresh buckets before the next attention read
            merged.append(leaf)
        elif name == "page_table":
            merged.append(leaf.at[row].set(jnp.asarray(row_table, jnp.int32)))
        elif name == "pos":
            row_pos = jnp.full((C,), -1, jnp.int32).at[:C_pre].set(one["pos"][0])
            merged.append(leaf.at[row].set(row_pos))
        elif name == "cur":
            merged.append(jnp.maximum(leaf, one["cur"]))
        elif _is_pooled(path):
            src = flat_one[path]
            lead = leaf.ndim - _POOL_NDIM[name]
            tail = src.shape[lead + 2:]
            srcb = src[(slice(None),) * lead + (0,)]
            srcb = srcb.reshape(src.shape[:lead] + (nbp, ps) + tail)
            idx = (slice(None),) * lead + (jnp.asarray(row_table)[:nbp],)
            merged.append(leaf.at[idx].set(srcb.astype(leaf.dtype)))
        else:
            src = flat_one[path]
            lead = (leaf.ndim - _BASE_NDIM[name]
                    if path.startswith("layers/") else 0)
            idx = (slice(None),) * lead + (row,)
            merged.append(leaf.at[idx].set(src[(slice(None),) * lead + (0,)]))
    treedef = jax.tree_util.tree_structure(cache)
    return jax.tree_util.tree_unflatten(treedef, merged)


# per-leaf rank of a single-sequence (no stacked-layer axes) cache entry;
# any extra leading axes are layer stacks, so batch axis = ndim - base rank
_BASE_NDIM = {"k": 4, "v": 4, "ck": 4, "cv": 4, "c": 3, "kr": 3,
              "ssm": 4, "x": 3, "bc": 3}


def merge_cache_row(cache: dict, one: dict, row: int) -> dict:
    """Write the single-sequence cache ``one`` (batch=1, same capacity) into
    batch row ``row`` of ``cache`` — slot admission for continuous batching.

    The row is replaced wholesale (KV slots, positions, SSM states), so no
    stale slot of the previous occupant survives: the admitted sequence's
    prompt KV lives at slots ``0..P-1`` (``one`` was prefilled from
    ``cur=0``), every other slot has ``pos=-1``, and attention masks by
    position, not slot order.  The shared ring pointer advances to
    ``max(cur, one_cur)`` so subsequent batch-wide decode writes land past
    the admitted prompt; collisions can only occur once ``cur`` wraps the
    capacity, i.e. capacity must cover the batch-lifetime token count (the
    same contract as the non-recycling path).
    """
    from repro.utils.treeutil import tree_flatten_with_paths

    flat = tree_flatten_with_paths(cache)
    one_flat = dict(tree_flatten_with_paths(one))
    merged = []
    for path, leaf in flat:
        src = one_flat[path]
        name = path.split("/")[-1]
        if name == "cur":
            merged.append(jnp.maximum(leaf, src))
            continue
        lead = leaf.ndim - _BASE_NDIM[name] if path.startswith("layers/") else 0
        idx = (slice(None),) * lead + (row,)
        merged.append(leaf.at[idx].set(src[(slice(None),) * lead + (0,)]))
    treedef = jax.tree_util.tree_structure(cache)
    return jax.tree_util.tree_unflatten(treedef, merged)


# recurrent (non-slot-addressed) state: advances in place each step, so an
# inactive row's update must be rolled back rather than position-masked
_RECURRENT = ("ssm", "x", "bc")


def freeze_inactive_rows(new_cache: dict, old_cache: dict, active) -> dict:
    """Roll back recurrent-state rows for sequences with ``active=False``.

    KV caches are slot-addressed and masked by position, so a finished
    sequence's writes can be made invisible by writing ``pos=-1``; SSM /
    conv states are cumulative — stepping them with a PAD token pollutes the
    row for later forced rollouts.  Restores the pre-step rows (tiny arrays:
    per-layer state, not the KV cache) for ssm/hybrid caches; a no-op tree
    for attention-only caches.
    """
    from repro.utils.treeutil import tree_flatten_with_paths

    flat_new = tree_flatten_with_paths(new_cache)
    old = dict(tree_flatten_with_paths(old_cache))
    merged = []
    for path, leaf in flat_new:
        name = path.split("/")[-1]
        if name in _RECURRENT:
            lead = leaf.ndim - _BASE_NDIM[name]
            mask = active.reshape((1,) * lead + (-1,) + (1,) * (leaf.ndim - lead - 1))
            leaf = jnp.where(mask, leaf, old[path])
        merged.append(leaf)
    treedef = jax.tree_util.tree_structure(new_cache)
    return jax.tree_util.tree_unflatten(treedef, merged)


def cache_pspecs(cfg: ModelConfig, ctx: ShardCtx, cache) -> dict:
    """PartitionSpec pytree for a cache (for jit in/out shardings).

    Paged caches (``page_table`` present): the page POOLS shard over the
    model axis — kv-heads when divisible, else the page_size axis (the
    paged analog of capacity-sharding) — and replicate over the data axis
    (pages are shared by all batch rows, so there is no batch dim to ride
    it); page tables and the logical ``pos`` replicate / ride data exactly
    like the ring metadata.
    """
    if ctx.mesh is None:
        return jax.tree_util.tree_map(lambda _: P(), cache)
    m = ctx.model_axis
    ms = ctx.model_size
    kv_on_model = cfg.n_kv_heads % ms == 0 and cfg.mla is None
    paged = "page_table" in cache
    # batch=1 shapes (long_500k) cannot shard the batch axis
    bsz = cache["pos"].shape[0] if hasattr(cache["pos"], "shape") else 1
    b = ctx.batch_entry_for(bsz)

    def pool_spec_for(path_leaf: str, lead: int) -> P:
        # pooled entries: (lead..., num_pages, page_size, ...tail)
        if path_leaf in ("k", "v"):
            if kv_on_model:
                return P(*([None] * lead), None, None, m, None)
            return P(*([None] * lead), None, m, None, None)  # shard page_size
        return P(*([None] * lead), None, m, None)            # c/kr
    def spec_for(path_leaf: str, ndim: int, lead: int) -> P:
        # lead = number of stacked layer axes before the batch axis
        if path_leaf == "page_table":
            return P(None, None)                             # replicated
        if path_leaf in ("pages", "logical"):
            return P(None, None)      # blocks/ page lists: replicated int32
        if path_leaf == "count":
            return P(None)
        if path_leaf in ("k", "v", "ck", "cv"):
            if kv_on_model:
                return P(*([None] * lead), b, None, m, None)
            return P(*([None] * lead), b, m, None, None)  # shard capacity
        if path_leaf in ("c", "kr"):
            return P(*([None] * lead), b, m, None)        # shard capacity
        if path_leaf == "ssm":
            return P(*([None] * lead), b, m, None, None)  # shard SSD heads
        if path_leaf == "x":
            return P(*([None] * lead), b, None, m)        # conv x channels
        if path_leaf == "bc":
            return P(*([None] * lead), b, None, None)
        if path_leaf in ("pos", "enc_pos"):
            return P(b, None)
        return P()

    from repro.utils.treeutil import tree_flatten_with_paths

    flat = tree_flatten_with_paths(cache)
    specs = []
    for path, leaf in flat:
        parts = path.split("/")
        leafname = parts[-1]
        if leafname == "cur":
            specs.append(P())
            continue
        # count stacked lead axes: layers/<seg>/... entries have ndim-known
        if paged and _is_pooled(path):
            specs.append(pool_spec_for(leafname, leaf.ndim - _POOL_NDIM[leafname]))
            continue
        lead = 0
        if parts[0] == "layers":
            lead = leaf.ndim - _BASE_NDIM[leafname]
        specs.append(spec_for(leafname, leaf.ndim, lead))
    treedef = jax.tree_util.tree_structure(cache)
    return jax.tree_util.tree_unflatten(treedef, specs)


def cache_bytes(cache) -> int:
    from repro.utils.treeutil import param_bytes

    return param_bytes(cache)
