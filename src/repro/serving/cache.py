"""Decode-state allocation: KV caches, MLA latent caches, SSM states.

Layout contract (consumed by ``models.transformer.forward_cached``):

  cache = {
    "layers": { <segment>: <stacked entries> },
    "pos":  (B, C) int32  — absolute position held in each slot, -1 = empty,
    "cur":  ()   int32    — committed length (ring: total tokens seen),
    ["enc_pos"]: (B, T)   — encoder positions (encdec only),
  }

Segments mirror the parameter stack segments:
  dense/vlm : {"seg":       {"k","v": (L,B,C,Hkv,hd)}}
  moe       : {"dense_seg": ..., "moe_seg": ...}
  mla (moe) : entries {"c": (L,B,C,r), "kr": (L,B,C,rope_d)}
  encdec    : {"dec_seg":   {"k","v", "ck","cv": (L,B,T,Hkv,hd)}}
  ssm       : {"seg":       {"ssm": (L,B,nh,N,hp), "conv": {...}}}
  hybrid    : {"ssm_seg": (G,n_per,B,...), "attn_seg": {"k","v": (G,B,C,H,hd)}}

Sliding-window configs use a ring buffer: capacity == window and slots are
``(cur + arange(m)) % capacity`` (see ``write_slots``); masking relies on the
explicit ``pos`` array, so ring order is irrelevant to attention.

Sharding (DESIGN.md §7): batch -> (pod,data); kv-heads -> model when
divisible, otherwise the capacity dim C -> model (GSPMD inserts the
partial-softmax collectives); MLA latent and SSM state follow the same rule
(C -> model for MLA; SSD heads -> model for SSM).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.ssm import ssm_dims
from repro.models.transformer import write_slots  # noqa: F401  (re-export)
from repro.sharding.partition import ShardCtx


def _attn_entry(cfg: ModelConfig, lead: tuple[int, ...], B: int, C: int, dtype):
    hd = cfg.resolved_head_dim
    if cfg.mla is not None:
        m = cfg.mla
        return {
            "c": jnp.zeros(lead + (B, C, m.kv_lora_rank), dtype),
            "kr": jnp.zeros(lead + (B, C, m.qk_rope_head_dim), dtype),
        }
    return {
        "k": jnp.zeros(lead + (B, C, cfg.n_kv_heads, hd), dtype),
        "v": jnp.zeros(lead + (B, C, cfg.n_kv_heads, hd), dtype),
    }


def _ssm_entry(cfg: ModelConfig, lead: tuple[int, ...], B: int, dtype):
    dm = ssm_dims(cfg)
    gn = dm.n_groups * dm.d_state
    return {
        "ssm": jnp.zeros(lead + (B, dm.n_heads, dm.d_state, dm.head_dim), jnp.float32),
        "conv": {
            "x": jnp.zeros(lead + (B, dm.conv_width - 1, dm.d_inner), dtype),
            "bc": jnp.zeros(lead + (B, dm.conv_width - 1, 2 * gn), dtype),
        },
    }


def alloc_cache(cfg: ModelConfig, batch: int, capacity: int, dtype=None) -> dict:
    """Allocate an empty cache with ``capacity`` kv slots per sequence."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    B, C = batch, capacity
    cache: dict = {
        "pos": jnp.full((B, C), -1, jnp.int32),
        "cur": jnp.zeros((), jnp.int32),
    }
    if cfg.arch_type in ("dense", "vlm"):
        cache["layers"] = {"seg": _attn_entry(cfg, (cfg.n_layers,), B, C, dtype)}
    elif cfg.arch_type == "moe":
        fk = cfg.moe.first_k_dense
        layers = {}
        if fk:
            layers["dense_seg"] = _attn_entry(cfg, (fk,), B, C, dtype)
        layers["moe_seg"] = _attn_entry(cfg, (cfg.n_layers - fk,), B, C, dtype)
        cache["layers"] = layers
    elif cfg.arch_type == "encdec":
        T = cfg.encoder_len
        entry = _attn_entry(cfg, (cfg.n_layers,), B, C, dtype)
        hd = cfg.resolved_head_dim
        entry["ck"] = jnp.zeros((cfg.n_layers, B, T, cfg.n_kv_heads, hd), dtype)
        entry["cv"] = jnp.zeros((cfg.n_layers, B, T, cfg.n_kv_heads, hd), dtype)
        cache["layers"] = {"dec_seg": entry}
        cache["enc_pos"] = jnp.zeros((B, T), jnp.int32)
    elif cfg.arch_type == "ssm":
        cache["layers"] = {"seg": _ssm_entry(cfg, (cfg.n_layers,), B, dtype)}
    elif cfg.arch_type == "hybrid":
        pat = cfg.hybrid_pattern
        n_per = sum(1 for k in pat if k == "ssm")
        G = cfg.n_layers // len(pat)
        cache["layers"] = {
            "ssm_seg": _ssm_entry(cfg, (G, n_per), B, dtype),
            "attn_seg": _attn_entry(cfg, (G,), B, C, dtype),
        }
    else:
        raise ValueError(cfg.arch_type)
    return cache




# per-leaf rank of a single-sequence (no stacked-layer axes) cache entry;
# any extra leading axes are layer stacks, so batch axis = ndim - base rank
_BASE_NDIM = {"k": 4, "v": 4, "ck": 4, "cv": 4, "c": 3, "kr": 3,
              "ssm": 4, "x": 3, "bc": 3}


def merge_cache_row(cache: dict, one: dict, row: int) -> dict:
    """Write the single-sequence cache ``one`` (batch=1, same capacity) into
    batch row ``row`` of ``cache`` — slot admission for continuous batching.

    The row is replaced wholesale (KV slots, positions, SSM states), so no
    stale slot of the previous occupant survives: the admitted sequence's
    prompt KV lives at slots ``0..P-1`` (``one`` was prefilled from
    ``cur=0``), every other slot has ``pos=-1``, and attention masks by
    position, not slot order.  The shared ring pointer advances to
    ``max(cur, one_cur)`` so subsequent batch-wide decode writes land past
    the admitted prompt; collisions can only occur once ``cur`` wraps the
    capacity, i.e. capacity must cover the batch-lifetime token count (the
    same contract as the non-recycling path).
    """
    from repro.utils.treeutil import tree_flatten_with_paths

    flat = tree_flatten_with_paths(cache)
    one_flat = dict(tree_flatten_with_paths(one))
    merged = []
    for path, leaf in flat:
        src = one_flat[path]
        name = path.split("/")[-1]
        if name == "cur":
            merged.append(jnp.maximum(leaf, src))
            continue
        lead = leaf.ndim - _BASE_NDIM[name] if path.startswith("layers/") else 0
        idx = (slice(None),) * lead + (row,)
        merged.append(leaf.at[idx].set(src[(slice(None),) * lead + (0,)]))
    treedef = jax.tree_util.tree_structure(cache)
    return jax.tree_util.tree_unflatten(treedef, merged)


# recurrent (non-slot-addressed) state: advances in place each step, so an
# inactive row's update must be rolled back rather than position-masked
_RECURRENT = ("ssm", "x", "bc")


def freeze_inactive_rows(new_cache: dict, old_cache: dict, active) -> dict:
    """Roll back recurrent-state rows for sequences with ``active=False``.

    KV caches are slot-addressed and masked by position, so a finished
    sequence's writes can be made invisible by writing ``pos=-1``; SSM /
    conv states are cumulative — stepping them with a PAD token pollutes the
    row for later forced rollouts.  Restores the pre-step rows (tiny arrays:
    per-layer state, not the KV cache) for ssm/hybrid caches; a no-op tree
    for attention-only caches.
    """
    from repro.utils.treeutil import tree_flatten_with_paths

    flat_new = tree_flatten_with_paths(new_cache)
    old = dict(tree_flatten_with_paths(old_cache))
    merged = []
    for path, leaf in flat_new:
        name = path.split("/")[-1]
        if name in _RECURRENT:
            lead = leaf.ndim - _BASE_NDIM[name]
            mask = active.reshape((1,) * lead + (-1,) + (1,) * (leaf.ndim - lead - 1))
            leaf = jnp.where(mask, leaf, old[path])
        merged.append(leaf)
    treedef = jax.tree_util.tree_structure(new_cache)
    return jax.tree_util.tree_unflatten(treedef, merged)


def cache_pspecs(cfg: ModelConfig, ctx: ShardCtx, cache) -> dict:
    """PartitionSpec pytree for a cache (for jit in/out shardings)."""
    if ctx.mesh is None:
        return jax.tree_util.tree_map(lambda _: P(), cache)
    m = ctx.model_axis
    ms = ctx.model_size
    kv_on_model = cfg.n_kv_heads % ms == 0 and cfg.mla is None
    # batch=1 shapes (long_500k) cannot shard the batch axis
    bsz = cache["pos"].shape[0] if hasattr(cache["pos"], "shape") else 1
    b = ctx.batch_entry_for(bsz)

    def spec_for(path_leaf: str, ndim: int, lead: int) -> P:
        # lead = number of stacked layer axes before the batch axis
        if path_leaf in ("k", "v", "ck", "cv"):
            if kv_on_model:
                return P(*([None] * lead), b, None, m, None)
            return P(*([None] * lead), b, m, None, None)  # shard capacity
        if path_leaf in ("c", "kr"):
            return P(*([None] * lead), b, m, None)        # shard capacity
        if path_leaf == "ssm":
            return P(*([None] * lead), b, m, None, None)  # shard SSD heads
        if path_leaf == "x":
            return P(*([None] * lead), b, None, m)        # conv x channels
        if path_leaf == "bc":
            return P(*([None] * lead), b, None, None)
        if path_leaf in ("pos", "enc_pos"):
            return P(b, None)
        return P()

    from repro.utils.treeutil import tree_flatten_with_paths

    flat = tree_flatten_with_paths(cache)
    specs = []
    for path, leaf in flat:
        parts = path.split("/")
        leafname = parts[-1]
        if leafname == "cur":
            specs.append(P())
            continue
        # count stacked lead axes: layers/<seg>/... entries have ndim-known
        lead = 0
        if parts[0] == "layers":
            lead = leaf.ndim - _BASE_NDIM[leafname]
        specs.append(spec_for(leafname, leaf.ndim, lead))
    treedef = jax.tree_util.tree_structure(cache)
    return jax.tree_util.tree_unflatten(treedef, specs)


def cache_bytes(cache) -> int:
    from repro.utils.treeutil import param_bytes

    return param_bytes(cache)
