"""Request layer: the per-request lifecycle state machine.

Top of the three-layer serving stack (``request`` -> ``scheduler`` ->
``executor``; see docs/architecture.md).  Contract: a ``Request`` is pure
host-side metadata — the prompt, the lifecycle status, the EAT trace
snapshots the serve loop records at chunk boundaries, and the exit-reason
tag set at harvest.  The no-jax-on-host rule applies: nothing in this
module (or ``scheduler``) may import jax or hold device arrays — the
device-resident counterpart of a DECODING request is one batch row of the
executor's ``ServeState``, reached only through executor programs, and the
serve loop converts between the two exactly once per chunk boundary.

Lifecycle::

    QUEUED --admit()--> PREFILLING --begin_decode()--> DECODING
                                                           |
                                     finish() --> EXITED (eat | end_think)
                                              \\-> EXHAUSTED (budget)

Transitions are enforced — a scheduler bug that double-admits a request or
harvests a queued one raises immediately instead of corrupting results.

Under the overlapped serve loop (``serving.pipeline``) a request also
carries IN_FLIGHT bookkeeping: ``admitted_fence`` records the dispatch
fence open when the slot was granted (the pipeline skips the row in that
fence's snapshot — the data there belongs to the slot's previous
occupant), and ``submitted_at``/``latency_s`` give the per-request latency
the scaling benchmark reports as percentiles.
"""
from __future__ import annotations

import dataclasses
import enum
import time
from typing import Optional


class RequestStatus(enum.Enum):
    QUEUED = "queued"
    PREFILLING = "prefilling"
    DECODING = "decoding"
    EXITED = "exited"          # EAT early exit or natural </think>
    EXHAUSTED = "exhausted"    # hit the reasoning-token budget


#: exit_reason values a finished request can carry
EXIT_EAT = "eat"               # EAT monitor latched stop (paper Alg. 1)
EXIT_END_THINK = "end_think"   # model emitted </think> on its own
EXIT_BUDGET = "budget"         # token budget exhausted

_TERMINAL = (RequestStatus.EXITED, RequestStatus.EXHAUSTED)


@dataclasses.dataclass
class Request:
    """One serving request and everything the host tracks about it."""

    rid: int
    prompt: "object"               # (S,) token ids (np array / list)
    prompt_len: int
    status: RequestStatus = RequestStatus.QUEUED
    slot: Optional[int] = None
    # chunk-boundary snapshots while DECODING: (n_reasoning, n_evals,
    # ema_var) triples — the request's EAT trajectory as the monitor saw it
    eat_trace: list = dataclasses.field(default_factory=list)
    exit_reason: Optional[str] = None
    result: Optional[dict] = None
    # wall-clock submission stamp (set by the serve loop's setup) — when
    # present, finish() derives result["latency_s"] from it
    submitted_at: Optional[float] = None
    # overlap-mode IN_FLIGHT bookkeeping: the dispatch fence open when the
    # slot was granted (see InFlightLedger.admitted_after)
    admitted_fence: Optional[int] = None

    # ------------------------------------------------------- transitions
    def _expect(self, *allowed: RequestStatus):
        if self.status not in allowed:
            raise RuntimeError(
                f"request {self.rid}: illegal transition from {self.status} "
                f"(expected one of {[a.value for a in allowed]})"
            )

    def admit(self, slot: int) -> None:
        """QUEUED -> PREFILLING: the scheduler granted batch ``slot``."""
        self._expect(RequestStatus.QUEUED)
        self.status = RequestStatus.PREFILLING
        self.slot = slot

    def begin_decode(self) -> None:
        """PREFILLING -> DECODING: the prefilled row is live in the batch."""
        self._expect(RequestStatus.PREFILLING)
        self.status = RequestStatus.DECODING

    def record_trace(self, n_reasoning: int, n_evals: int, ema_var: float) -> None:
        if self.status is RequestStatus.DECODING:
            self.eat_trace.append((int(n_reasoning), int(n_evals),
                                   float(ema_var)))

    def finish(self, *, reasoning_tokens, n_reasoning: int, ended_think: bool,
               eat_stop: bool, answer_tokens=None) -> None:
        """DECODING -> EXITED/EXHAUSTED with exit-reason metadata.

        Reason precedence mirrors the engine's exit latch: the EAT stop and
        the ``</think>`` check both beat the budget check (the budget only
        fires when neither latched in the same device step).
        """
        self._expect(RequestStatus.DECODING)
        if eat_stop:
            self.exit_reason = EXIT_EAT
        elif ended_think:
            self.exit_reason = EXIT_END_THINK
        else:
            self.exit_reason = EXIT_BUDGET
        self.status = (RequestStatus.EXHAUSTED
                       if self.exit_reason == EXIT_BUDGET
                       else RequestStatus.EXITED)
        self.result = {
            "request": self.rid,
            "reasoning_tokens": reasoning_tokens,
            "n_reasoning": int(n_reasoning),
            "ended_think": bool(ended_think),
            "exit_reason": self.exit_reason,
            "status": self.status.value,
        }
        if answer_tokens is not None:
            self.result["answer_tokens"] = answer_tokens
        if self.submitted_at is not None:
            self.result["latency_s"] = time.perf_counter() - self.submitted_at
        self.slot = None

    # ----------------------------------------------------------- queries
    @property
    def done(self) -> bool:
        return self.status in _TERMINAL

    def to_result(self) -> dict:
        if self.result is None:
            raise RuntimeError(f"request {self.rid} never finished "
                               f"(status={self.status.value})")
        out = dict(self.result)
        out["eat_trace"] = list(self.eat_trace)
        return out
