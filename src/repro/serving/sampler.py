"""Token sampling (temperature / top-p), jit-friendly, padded-vocab aware.

The paper's decoding config (App. H): temperature 0.6, top-p 0.95 (the
DeepSeek model-card recommendation); greedy for confidence rollouts.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SamplerConfig:
    temperature: float = 0.6
    top_p: float = 0.95
    greedy: bool = False


def _mask_padded(logits: jax.Array, vocab: int) -> jax.Array:
    Vp = logits.shape[-1]
    if vocab < Vp:
        logits = jnp.where(jnp.arange(Vp) < vocab, logits, -jnp.inf)
    return logits


def sample(
    rng: jax.Array,
    logits: jax.Array,        # (B, Vp)
    vocab: int,
    cfg: SamplerConfig = SamplerConfig(),
) -> jax.Array:               # (B,) int32
    lf = _mask_padded(logits.astype(jnp.float32), vocab)
    if cfg.greedy:
        return jnp.argmax(lf, axis=-1).astype(jnp.int32)
    lf = lf / jnp.maximum(cfg.temperature, 1e-6)
    if cfg.top_p < 1.0:
        probs = jax.nn.softmax(lf, axis=-1)
        srt = jnp.sort(probs, axis=-1)[:, ::-1]
        cum = jnp.cumsum(srt, axis=-1)
        # smallest set with cumulative mass >= top_p: keep probs >= cutoff
        idx = jnp.sum(cum < cfg.top_p, axis=-1, keepdims=True)   # first idx reaching p
        cutoff = jnp.take_along_axis(srt, idx, axis=-1)
        lf = jnp.where(probs >= cutoff, lf, -jnp.inf)
    return jax.random.categorical(rng, lf, axis=-1).astype(jnp.int32)


def logprob_of(logits: jax.Array, token: jax.Array, vocab: int) -> jax.Array:
    """log p(token) under softmax(logits[:, :vocab]).  logits (B,Vp), token (B,)."""
    lf = _mask_padded(logits.astype(jnp.float32), vocab)
    logp = jax.nn.log_softmax(lf, axis=-1)
    return jnp.take_along_axis(logp, token[:, None], axis=-1)[:, 0]
