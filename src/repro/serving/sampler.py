"""Token sampling (temperature / top-k / top-p / typical-p / min-p),
jit-friendly, padded-vocab aware.

The paper's decoding config (App. H): temperature 0.6, top-p 0.95 (the
DeepSeek model-card recommendation); greedy for confidence rollouts.
``top_k``, ``typical_p`` and ``min_p`` are serving-stack extras (all off by
default): filters apply in the conventional order top-k -> top-p ->
typical-p -> min-p, each masking logits to -inf so the final categorical
renormalizes over the surviving set (``filter_logits`` exposes the masking
math for unit tests).  Typical-p (Meister et al. 2022, locally typical
sampling) keeps the smallest set of tokens — ranked by closeness of their
surprisal to the distribution's entropy — whose mass reaches ``typical_p``;
unlike the other filters it can drop the argmax (a very peaked distribution
makes the top token atypical), but it always keeps the most typical one.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SamplerConfig:
    temperature: float = 0.6
    top_p: float = 0.95
    top_k: int = 0            # keep the k highest-prob tokens (0 = off)
    typical_p: float = 1.0    # keep the most locally-typical mass (1 = off)
    min_p: float = 0.0        # drop tokens with p < min_p * max_p (0 = off)
    greedy: bool = False


def _mask_padded(logits: jax.Array, vocab: int) -> jax.Array:
    Vp = logits.shape[-1]
    if vocab < Vp:
        logits = jnp.where(jnp.arange(Vp) < vocab, logits, -jnp.inf)
    return logits


def filter_logits(
    lf: jax.Array,            # (B, Vp) float32, temperature already applied
    cfg: SamplerConfig,
) -> jax.Array:
    """Apply the top-k / top-p / typical-p / min-p cutoffs as -inf masks.

    Top-k, top-p and min-p each keep at least the argmax token: top-k by
    construction (k >= 1 keeps the largest logit), top-p because the cutoff
    is the first sorted prob reaching the mass (the max always qualifies),
    min-p because ``max_p >= min_p * max_p`` for ``min_p <= 1``.  Typical-p
    keeps at least the MOST TYPICAL token (the one whose surprisal is
    closest to the entropy) — which for a peaked distribution may not be
    the argmax — so no filter can empty a row.
    """
    if cfg.top_k > 0 and cfg.top_k < lf.shape[-1]:
        # kth-largest logit per row (ties at the threshold all survive);
        # lax.top_k, not a full-vocab sort — this runs every decode step
        kth = jax.lax.top_k(lf, cfg.top_k)[0][:, -1:]
        lf = jnp.where(lf >= kth, lf, -jnp.inf)
    if cfg.top_p < 1.0:
        probs = jax.nn.softmax(lf, axis=-1)
        srt = jnp.sort(probs, axis=-1)[:, ::-1]
        cum = jnp.cumsum(srt, axis=-1)
        # smallest set with cumulative mass >= top_p: keep probs >= cutoff
        idx = jnp.sum(cum < cfg.top_p, axis=-1, keepdims=True)   # first idx reaching p
        cutoff = jnp.take_along_axis(srt, idx, axis=-1)
        lf = jnp.where(probs >= cutoff, lf, -jnp.inf)
    if cfg.typical_p < 1.0:
        logp = jax.nn.log_softmax(lf, axis=-1)
        probs = jnp.exp(logp)
        # H = -sum p log p over the surviving set (-inf rows contribute 0)
        ent = -jnp.sum(jnp.where(probs > 0, probs * logp, 0.0),
                       axis=-1, keepdims=True)
        score = jnp.abs(-logp - ent)          # masked tokens score +inf
        order = jnp.argsort(score, axis=-1)   # most typical first
        cum = jnp.cumsum(jnp.take_along_axis(probs, order, axis=-1), axis=-1)
        # smallest typical set with mass >= typical_p: cutoff at the first
        # sorted score reaching it (score ties at the cutoff all survive)
        idx = jnp.sum(cum < cfg.typical_p, axis=-1, keepdims=True)
        cutoff = jnp.take_along_axis(
            jnp.take_along_axis(score, order, axis=-1), idx, axis=-1)
        lf = jnp.where(score <= cutoff, lf, -jnp.inf)
    if cfg.min_p > 0.0:
        probs = jax.nn.softmax(lf, axis=-1)
        cutoff = cfg.min_p * probs.max(axis=-1, keepdims=True)
        lf = jnp.where(probs >= cutoff, lf, -jnp.inf)
    return lf


def sample(
    rng: jax.Array,
    logits: jax.Array,        # (B, Vp)
    vocab: int,
    cfg: SamplerConfig = SamplerConfig(),
) -> jax.Array:               # (B,) int32
    lf = _mask_padded(logits.astype(jnp.float32), vocab)
    if cfg.greedy:
        return jnp.argmax(lf, axis=-1).astype(jnp.int32)
    lf = lf / jnp.maximum(cfg.temperature, 1e-6)
    lf = filter_logits(lf, cfg)
    return jax.random.categorical(rng, lf, axis=-1).astype(jnp.int32)


def logprob_of(logits: jax.Array, token: jax.Array, vocab: int) -> jax.Array:
    """log p(token) under softmax(logits[:, :vocab]).  logits (B,Vp), token (B,)."""
    lf = _mask_padded(logits.astype(jnp.float32), vocab)
    logp = jax.nn.log_softmax(lf, axis=-1)
    return jnp.take_along_axis(logp, token[:, None], axis=-1)[:, 0]
