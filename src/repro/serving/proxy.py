"""Black-box EAT monitoring with a proxy model (paper §4.2, Fig. 5).

The reasoning model theta is a black box: only its *verbal* token stream is
visible (e.g. a streaming API).  A small local proxy model phi maintains its
own KV cache over the same stream — chunks are prefilled as they arrive —
and EAT is computed from phi's next-token distribution after a virtual
``</think>`` (+ prefix).  Because chunk prefill + probe on the small proxy
is much faster than the big model's generation (Fig. 5b), monitoring
overlaps with the stream and adds no wall-clock latency; we measure that
headroom in benchmarks/fig5_blackbox.py.

NOTE: theta and phi must share a tokenizer family for the stream to be
re-tokenized faithfully (the paper pairs DeepSeek-R1 distills, or
re-tokenizes Claude text with Qwen's tokenizer).  In this framework both
ends speak the synthetic task tokenizer.

Two layers live here:

* ``ProxyMonitor`` — the standalone streaming monitor the examples drive by
  hand (one prefill+probe per arriving chunk, host loop);
* ``ProxyConfig`` + ``ProxyTier`` — the serving-stack integration: one
  ``ProxyTier`` per ``serve()`` run orchestrates a
  ``serving.executor.ProxyExecutor`` (shadow-decode programs, own KV
  cache/page pool) in lock-step with the generator's scheduler — prompt
  prefills at admission, page bookkeeping before each chunk, page frees at
  harvest — so proxy-driven exits recycle slots and pages exactly like
  self-EAT exits.  ``ReasoningEngine(..., proxy=ProxyConfig(...))`` turns
  it on (``monitor="proxy"`` mode; docs/serving.md §Black-box monitoring).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.monitor import MonitorState, ReasoningMonitor
from repro.models.model import Model
from repro.serving.cache import (
    CacheConfig,
    alloc_cache,
    alloc_paged_template,
    page_align,
)
from repro.serving.executor import (
    ProxyExecutor,
    ServeState,
    build_stream_monitor_programs,
    positions_for,
)
from repro.serving.scheduler import PageAllocator


@dataclasses.dataclass
class ProxyMonitor:
    """Streaming EAT monitor around a proxy model."""

    model: Model
    params: dict
    monitor: ReasoningMonitor
    capacity: int = 2048

    def __post_init__(self):
        # every jitted program comes from the executor layer — proxy.py is
        # host orchestration only (the layering contract, tools/audit)
        self._consume, self._probe, self._prefill = \
            build_stream_monitor_programs(self.model, self.monitor.probe)

    def start(self, prompts: jax.Array, prompt_len: jax.Array):
        """Feed the question prompt (left-padded).  Returns opaque state."""
        B, S = prompts.shape
        pad = S - prompt_len
        pos1d = jnp.arange(S, dtype=jnp.int32)[None, :] - pad[:, None]
        pos1d = jnp.where(pos1d >= 0, pos1d, -1)
        cache = alloc_cache(self.model.cfg, B, self.capacity)
        pos3 = (jnp.broadcast_to(pos1d[..., None], pos1d.shape + (3,))
                if self.model.cfg.mrope_sections else pos1d)
        _, cache = self._prefill(self.params, prompts, pos3, pos1d, cache)
        return {
            "cache": cache,
            "next_pos": prompt_len.astype(jnp.int32),
            "monitor": self.monitor.init(B),
            "probe_seconds": [],
        }

    def observe_chunk(self, state: dict, chunk: jax.Array,
                      active: jax.Array | None = None, *,
                      next_pos: jax.Array | None = None) -> dict:
        """Consume a chunk of streamed reasoning tokens and evaluate EAT.

        chunk: (B, c) token ids (PAD-right for finished sequences).
        ``next_pos`` (B,) is the authoritative stream offset from the
        generator's request state; when omitted the monitor falls back to
        its internal counter.  Pass it whenever rows can be re-seeded
        mid-stream (deferred admissions, slot recycling): the internal
        counter only tracks chunks THIS monitor consumed, so a recycled
        row's counter is stale and the probe would land at the previous
        occupant's offset.  Returns updated state;
        ``state['monitor'].stop_flag`` is the exit signal to send back to
        the black-box generator.
        """
        B, c = chunk.shape
        if active is None:
            active = jnp.ones((B,), bool)
        base_pos = (state["next_pos"] if next_pos is None
                    else jnp.asarray(next_pos, jnp.int32))
        t0 = time.perf_counter()
        cache, next_pos = self._consume(self.params, state["cache"], chunk, base_pos)
        eat = self._probe(self.params, cache, next_pos)
        eat.block_until_ready()
        dt = time.perf_counter() - t0
        due = jnp.ones((B,), bool)   # chunk arrival = evaluation point
        mon = self.monitor.update(state["monitor"], eat, due, active)
        return {
            "cache": cache,
            "next_pos": next_pos,
            "monitor": mon,
            "probe_seconds": state["probe_seconds"] + [dt],
            "last_eat": eat,
        }

    def should_stop(self, state: dict) -> jax.Array:
        return state["monitor"].stop_flag


# --------------------------------------------------------------------------
# Serving-stack integration: the proxy tier behind ``monitor="proxy"``
# --------------------------------------------------------------------------

@dataclasses.dataclass
class ProxyConfig:
    """The proxy tier's build recipe, handed to ``ReasoningEngine``.

    ``model``/``params`` are the monitor model phi — typically much smaller
    than the generator, possibly on its own (smaller) mesh via
    ``model.ctx``.  ``cache``/``capacity`` default to the engine's own
    backend and logical capacity (the proxy shadows the same stream, so the
    same sizing rules apply); override them to give the proxy its own page
    pool budget (``tests/test_proxy_serve.py`` exercises a deliberately
    undersized proxy pool deferring admissions independently of the
    generator's).
    """

    model: Model
    params: dict
    cache: Optional[CacheConfig] = None     # None -> inherit the engine's
    capacity: Optional[int] = None          # None -> EngineConfig.capacity


class ProxyTier:
    """One ``serve()`` run's host-side orchestration of the proxy tier.

    Owns the proxy's device state (a ``ServeState`` driven exclusively by
    ``ProxyExecutor`` programs) and its page allocator, and exposes the
    hooks the engine's serve loop calls at each lifecycle point:

        start_batch   prefill the initial cohort's prompts
        begin_chunk   map pages the shadow decode may write, push the table
        observe       shadow one generator chunk -> (new_n, proxy monitor)
        free_row      return an exiting row's proxy pages (harvest)
        can_admit     proxy-pool admission gate (defer, don't refuse)
        check_capacity  proxy ring-wrap guard (refuse, like the scheduler's)
        admit         prefill + merge an admitted prompt into a proxy slot

    The tier never sees generator logits and never decides tokens — it
    consumes the emitted stream and returns exit decisions, which the
    engine applies through the generator executor's ``retract`` program.
    """

    def __init__(self, executor: ProxyExecutor, params, ecfg,
                 monitor: ReasoningMonitor, cache_cfg: CacheConfig,
                 capacity: int, budget: int):
        self.ex = executor
        self.params = params
        self.ecfg = ecfg
        self.monitor = monitor
        self.ccfg = cache_cfg
        self.capacity = capacity
        self.budget = budget
        self.paged = cache_cfg.kind == "paged"
        self.probe_m = len(monitor.probe)
        self.state: ServeState | None = None
        self.alloc: PageAllocator | None = None
        self._C_pre: int | None = None

    # ------------------------------------------------------------ lifecycle
    def _fresh(self, prompts: jax.Array, prompt_len: jax.Array,
               capacity: int) -> ServeState:
        """Prompt-prefilled proxy state.  Unlike ``engine.start`` nothing is
        sampled — the proxy never chooses tokens, so ``rng``/``last_token``/
        ``out_tokens`` are inert placeholders; ``n_reasoning`` starts at 1
        to mirror the generator's already-emitted first token."""
        cfg = self.ex.cfg
        B, S = prompts.shape
        pad = S - prompt_len
        pos1d = jnp.arange(S, dtype=jnp.int32)[None, :] - pad[:, None]
        pos1d = jnp.where(pos1d >= 0, pos1d, -1)
        cache = alloc_cache(cfg, B, capacity)
        _, cache = self.ex.prefill(self.params, prompts,
                                   positions_for(cfg, pos1d), pos1d, cache)
        return ServeState(
            cache=cache,
            rng=jax.random.PRNGKey(0),
            active=jnp.ones((B,), bool),
            next_pos=prompt_len.astype(jnp.int32),
            last_token=jnp.zeros((B,), jnp.int32),
            n_reasoning=jnp.ones((B,), jnp.int32),
            monitor=self.monitor.init(B),
            ended_think=jnp.zeros((B,), bool),
            out_tokens=jnp.full((B, 1), self.ecfg.pad_id, jnp.int32),
            out_len=jnp.ones((B,), jnp.int32),
        )

    def start_batch(self, prompts_np, plen_np, rows: list[int]) -> None:
        """Prefill the initial cohort (same rows the scheduler admitted)."""
        B, S = prompts_np.shape
        prompts = jnp.asarray(prompts_np)
        plen = jnp.asarray(plen_np)
        if not self.paged:
            self.state = self._fresh(prompts, plen, self.capacity)
            return
        ps = self.ccfg.page_size
        C_log = page_align(self.capacity, ps)
        n_blocks = C_log // ps
        num_pages = self.ccfg.num_pages or (B * n_blocks + 1)
        self.alloc = PageAllocator(num_pages, ps, n_blocks, B,
                                   sizing_knob="ProxyConfig.cache.num_pages")
        self._C_pre = page_align(S, ps)
        st = self._fresh(prompts, plen, self._C_pre)
        for row in rows:
            self.alloc.ensure(row, 0, S - 1)
        # mirror the engine's template setup: page-native shadow decodes
        # read through the proxy pool's own compacted page list
        template = alloc_paged_template(
            self.ex.cfg, B, C_log, ps, num_pages, alloc=self.alloc,
            native=self.ccfg.attn_impl != "gather")
        self.state = st._replace(cache=self.ex.pack_paged(
            template, st.cache, self.alloc.table))

    # ------------------------------------------------------- chunk shadowing
    def begin_chunk(self, chunk_py: int, bound: list[int]) -> None:
        """Map (and push) pages covering the slots this chunk's shadow
        decode may write: up to ``chunk_py`` consumed tokens (clamped per
        row to its remaining budget) plus the probe tail — the same
        ``Executor.ensure_chunk_pages`` rule the generator loop uses, over
        the proxy's own pool and state."""
        if not self.paged:
            return
        self.state = self.ex.ensure_chunk_pages(
            self.alloc, self.state, bound, chunk_py + self.probe_m,
            tail=self.probe_m, budget=self.budget,
        )

    def observe(self, gen_out_tokens, n_start, n_emitted, chunk_py: int):
        """Shadow one generator chunk; returns ``(new_n, proxy monitor)``
        for the generator executor's ``retract``.  ``gen_out_tokens`` is the
        post-chunk emitted-token buffer; ``n_start``/``n_emitted`` the
        per-row host copies the engine took around the chunk dispatch."""
        self.state = self.ex.observe_chunk(
            self.params, self.state, gen_out_tokens, n_start, n_emitted,
            chunk_py,
        )
        return self.state.n_reasoning, self.state.monitor

    # ------------------------------------------------------ harvest / admit
    def free_row(self, slot: int) -> None:
        if self.paged:
            self.alloc.free_row(slot)

    def can_admit(self, prompt_tokens: int) -> bool:
        """Paged-pool admission gate — defers (stays queued), never raises."""
        return (not self.paged) or self.alloc.can_admit(prompt_tokens)

    def check_capacity(self, when: str) -> None:
        """Ring-wrap guard for an explicitly undersized proxy ring (the
        proxy's ``cur`` never outruns the generator's, so with inherited
        capacity the scheduler's own guard always fires first)."""
        if self.paged:
            return
        used = int(self.state.cache["cur"])
        if used + self.budget > self.capacity:
            raise RuntimeError(
                f"proxy cache capacity {self.capacity} cannot hold {when}: "
                f"{used} slots committed + up to {self.budget} decode steps "
                f"would wrap the proxy ring. Raise ProxyConfig.capacity "
                f"(or leave it None to inherit EngineConfig.capacity)."
            )

    def admit(self, slot: int, prompt_np, prompt_len: int, S: int) -> None:
        """Prefill + merge an admitted prompt into proxy ``slot`` — the
        lock-step mirror of the generator's admit/admit_paged dispatch."""
        one = self._fresh(jnp.asarray(prompt_np[None]),
                          jnp.asarray([prompt_len]),
                          self._C_pre if self.paged else self.capacity)
        if self.paged:
            row_table = self.alloc.admit_row(slot, S,
                                             int(self.state.cache["cur"]))
            self.state = self.ex.admit_paged(self.state, one, slot,
                                             row_table)
        else:
            self.state = self.ex.admit(self.state, one, slot)
