"""Black-box EAT monitoring with a proxy model (paper §4.2, Fig. 5).

The reasoning model theta is a black box: only its *verbal* token stream is
visible (e.g. a streaming API).  A small local proxy model phi maintains its
own KV cache over the same stream — chunks are prefilled as they arrive —
and EAT is computed from phi's next-token distribution after a virtual
``</think>`` (+ prefix).  Because chunk prefill + probe on the small proxy
is much faster than the big model's generation (Fig. 5b), monitoring
overlaps with the stream and adds no wall-clock latency; we measure that
headroom in benchmarks/fig5_blackbox.py.

NOTE: theta and phi must share a tokenizer family for the stream to be
re-tokenized faithfully (the paper pairs DeepSeek-R1 distills, or
re-tokenizes Claude text with Qwen's tokenizer).  In this framework both
ends speak the synthetic task tokenizer.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.core.eat import ProbeSpec, eval_eat
from repro.core.monitor import MonitorState, ReasoningMonitor
from repro.models.model import Model
from repro.serving.cache import alloc_cache


@dataclasses.dataclass
class ProxyMonitor:
    """Streaming EAT monitor around a proxy model."""

    model: Model
    params: dict
    monitor: ReasoningMonitor
    capacity: int = 2048

    def __post_init__(self):
        model = self.model

        def _positions(pos1d):
            if model.cfg.mrope_sections:
                return jnp.broadcast_to(pos1d[..., None], pos1d.shape + (3,))
            return pos1d

        @jax.jit
        def consume(params, cache, tokens, next_pos):
            B, m = tokens.shape
            pos1d = next_pos[:, None] + jnp.arange(m, dtype=jnp.int32)[None]
            _, cache = model.prefill(params, tokens, _positions(pos1d), pos1d, cache)
            return cache, next_pos + m

        @jax.jit
        def probe(params, cache, next_pos):
            return eval_eat(model, params, cache, self.monitor.probe, next_pos)

        self._consume = consume
        self._probe = probe

    def start(self, prompts: jax.Array, prompt_len: jax.Array):
        """Feed the question prompt (left-padded).  Returns opaque state."""
        B, S = prompts.shape
        pad = S - prompt_len
        pos1d = jnp.arange(S, dtype=jnp.int32)[None, :] - pad[:, None]
        pos1d = jnp.where(pos1d >= 0, pos1d, -1)
        cache = alloc_cache(self.model.cfg, B, self.capacity)
        pos3 = (jnp.broadcast_to(pos1d[..., None], pos1d.shape + (3,))
                if self.model.cfg.mrope_sections else pos1d)
        _, cache = jax.jit(self.model.prefill)(self.params, prompts, pos3, pos1d, cache)
        return {
            "cache": cache,
            "next_pos": prompt_len.astype(jnp.int32),
            "monitor": self.monitor.init(B),
            "probe_seconds": [],
        }

    def observe_chunk(self, state: dict, chunk: jax.Array,
                      active: jax.Array | None = None) -> dict:
        """Consume a chunk of streamed reasoning tokens and evaluate EAT.

        chunk: (B, c) token ids (PAD-right for finished sequences).
        Returns updated state; ``state['monitor'].stop_flag`` is the exit
        signal to send back to the black-box generator.
        """
        B, c = chunk.shape
        if active is None:
            active = jnp.ones((B,), bool)
        t0 = time.perf_counter()
        cache, next_pos = self._consume(self.params, state["cache"], chunk, state["next_pos"])
        eat = self._probe(self.params, cache, next_pos)
        eat.block_until_ready()
        dt = time.perf_counter() - t0
        due = jnp.ones((B,), bool)   # chunk arrival = evaluation point
        mon = self.monitor.update(state["monitor"], eat, due, active)
        return {
            "cache": cache,
            "next_pos": next_pos,
            "monitor": mon,
            "probe_seconds": state["probe_seconds"] + [dt],
            "last_eat": eat,
        }

    def should_stop(self, state: dict) -> jax.Array:
        return state["monitor"].stop_flag
