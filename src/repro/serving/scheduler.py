"""Scheduler layer: slot allocation + admission policy for continuous
batching.

Middle of the three-layer serving stack (``request`` -> ``scheduler`` ->
``executor``; see docs/architecture.md).  Contract: pure host-side Python —
deliberately NO jax import (numpy only, for the page table): every decision
here is a list/deque operation over ``Request`` objects, so the policy can
be unit-tested without touching a device and swapped (priority queues,
per-tenant fairness, paged admission) without re-tracing any program.  The
scheduler never holds device state; its device-facing outputs are plain
integers (slot ids) and the int32 page table the engine pushes to the
executor.

The policy is FIFO continuous batching: ``batch_size`` slots, a queue of
QUEUED requests, and the invariant that a slot freed by an early-exiting
sequence is refilled immediately (the executor's ``admit`` program merges
the freshly prefilled row in).

Capacity policy is per cache backend (``serving.cache.CacheConfig``):

* ring — the scheduler owns the cache-ring capacity guard: ``cur`` advances
  one shared slot per batch-wide decode step and never rewinds, so a wrap
  would silently overwrite live KV rows; ``check_capacity`` refuses the
  admission instead, making capacity a BATCH-LIFETIME bound.
* paged — ``PageAllocator`` turns the same check into per-block
  bookkeeping at admission time: admit whenever the free list covers the
  prompt blocks plus one decode page; an exiting request's pages return to
  the free list at harvest and immediately back the next admission.

With ``monitor="proxy"`` serving (docs/serving.md §Black-box monitoring)
an admission enters TWO caches — the generator's and the proxy tier's —
each with its own pool and allocator.  ``pools_can_admit`` is the combined
gate: the request stays queued (defers) unless EVERY pool can cover it, so
an exhausted proxy pool back-pressures admission independently of the
generator pool (and vice versa), and either pool's harvest-time frees can
be the ones that unblock it.
"""
from __future__ import annotations

import math
from collections import deque
from typing import Iterator, Optional

import numpy as np

from repro.serving.request import Request, RequestStatus


class SlotScheduler:
    """FIFO slot scheduler over a fixed-size continuous batch."""

    def __init__(self, requests: list[Request], batch_size: int, *,
                 capacity: int, budget: int):
        if batch_size < 1:
            raise ValueError("batch_size must be >= 1")
        self.requests = list(requests)
        self.queue: deque[Request] = deque(
            r for r in self.requests if r.status is RequestStatus.QUEUED
        )
        self.slots: list[Optional[Request]] = [None] * batch_size
        self.capacity = capacity
        self.budget = budget

    # ----------------------------------------------------------- admission
    def start_batch(self) -> list[Request]:
        """Admit the initial cohort: fill every slot from the queue (fewer
        requests than slots leaves the tail slots empty)."""
        cohort = []
        for slot in range(len(self.slots)):
            if not self.queue:
                break
            req = self.queue.popleft()
            req.admit(slot)
            self.slots[slot] = req
            cohort.append(req)
        return cohort

    def admit_next(self, slot: int) -> Optional[Request]:
        """Recycle a freed ``slot`` with the next queued request (None when
        the queue has drained).  The request comes back PREFILLING; the
        serve loop flips it to DECODING once its row is merged in."""
        if self.slots[slot] is not None:
            raise RuntimeError(f"slot {slot} is still occupied by request "
                               f"{self.slots[slot].rid}")
        if not self.queue:
            return None
        req = self.queue.popleft()
        req.admit(slot)
        self.slots[slot] = req
        return req

    # ------------------------------------------------------------- harvest
    def release(self, slot: int) -> Request:
        req = self.slots[slot]
        if req is None:
            raise RuntimeError(f"slot {slot} is already free")
        self.slots[slot] = None
        return req

    def finished_slots(self, active_mask) -> list[tuple[int, Request]]:
        """Slots whose resident request stopped decoding this chunk:
        ``active_mask`` is the host copy of ``ServeState.active``."""
        return [(s, r) for s, r in enumerate(self.slots)
                if r is not None and not bool(active_mask[s])]

    def bound(self) -> Iterator[tuple[int, Request]]:
        """(slot, request) pairs currently resident in the batch."""
        return ((s, r) for s, r in enumerate(self.slots) if r is not None)

    @property
    def running(self) -> bool:
        return any(r is not None for r in self.slots)

    @property
    def pending(self) -> int:
        return len(self.queue)

    # ------------------------------------------------------ capacity guard
    @staticmethod
    def required_capacity(prompt_width: int, n_requests: int,
                          batch_size: int, budget: int) -> int:
        """Cache slots needed for a batch-lifetime run of the ring cache:
        the shared ``cur`` pointer advances one slot per batch-wide decode
        step and never rewinds, so capacity must cover the prompt width
        plus every cohort's worst-case budget (one extra cohort of slack
        for admissions that straddle cohort boundaries).  The single
        sizing rule for every driver (CLI, benchmarks) of ``serve()``."""
        cohorts = math.ceil(n_requests / batch_size) + 1
        return prompt_width + cohorts * budget

    def check_capacity(self, used: int, when: str) -> None:
        """Refuse work that would wrap the shared cache ring.  ``used`` is
        the committed ring length (``int(state.cache['cur'])``)."""
        if used + self.budget > self.capacity:
            raise RuntimeError(
                f"EngineConfig.capacity={self.capacity} cannot hold "
                f"{when}: {used} slots committed + up to {self.budget} "
                f"decode steps would wrap the cache ring. Size capacity "
                f"to the batch-lifetime token count "
                f"(~prompt_width + ceil(n_requests / batch_size) * budget)."
            )


def pools_can_admit(prompt_tokens: int, *allocs) -> bool:
    """Admission gate across every page pool a request must enter (the
    generator's, plus the proxy tier's in ``monitor="proxy"`` serving).
    ``allocs`` entries may be None (that cache is a ring — no page gate) or
    a ``PageAllocator``; admission defers unless every pool present can
    cover the prompt blocks plus one decode page.  Deliberately all-or-
    nothing BEFORE any pool allocates, so a half-admitted request can never
    strand pages in one pool while waiting on the other."""
    return all(a.can_admit(prompt_tokens) for a in allocs if a is not None)


class PageAllocator:
    """Free-page bookkeeping for the block-paged KV cache (pure host).

    Owns the authoritative page table: a (batch, n_blocks) int32 array
    mapping each row's logical blocks (``slot // page_size``) to physical
    pages of the executor-side pool.  Page ``serving.cache.PAGE_TRASH`` (0)
    is reserved: unmapped entries point at it, so a row without a mapping
    writes into (and reads position-masked garbage from) the trash page
    instead of corrupting a neighbour.  The engine pushes ``table`` to the
    device before every chunk dispatch (replicated — a few KB of int32).

    This is what turns the ring cache's batch-lifetime capacity bound into
    per-block bookkeeping: ``can_admit`` asks only whether the free list
    covers the prompt plus one decode page, and ``free_row`` returns an
    exiting request's pages to the free list the moment it is harvested —
    in the same batch, those pages back the next admission.
    """

    def __init__(self, num_pages: int, page_size: int, n_blocks: int,
                 batch: int, *, sizing_knob: str = "CacheConfig.num_pages"):
        if num_pages < 2:
            raise ValueError("num_pages must be >= 2 (page 0 is reserved "
                             "as the trash page)")
        self.num_pages = num_pages
        self.page_size = page_size
        self.n_blocks = n_blocks
        # which config field the exhaustion error tells the operator to
        # raise — the proxy tier's pool is sized by ProxyConfig, not the
        # engine's CacheConfig
        self.sizing_knob = sizing_knob
        self.table = np.zeros((batch, n_blocks), np.int32)
        # LIFO free list -> a freed page is the next one handed out, which
        # maximises page reuse within a batch (and the reuse counter below
        # proves it happened)
        self.free: list[int] = list(range(num_pages - 1, 0, -1))
        self._owned: list[list[int]] = [[] for _ in range(batch)]
        self._ever_used: set[int] = set()
        self.pages_reused = 0
        self.peak_pages_in_use = 0
        # admission ATTEMPTS this pool gated (the request stayed queued
        # because THIS pool's free list could not cover it) — the engine
        # increments it per gated sweep attempt, so the same deferred
        # request re-attempted at a later chunk boundary (or into another
        # free slot) counts again; it distinguishes proxy-pool pressure
        # from generator-pool pressure in tests and stats
        self.deferrals = 0
        # True whenever self.table differs from the last snapshot() — the
        # engine skips the per-chunk host->device table upload when clean
        self.dirty = True

    # ------------------------------------------------------------- queries
    @property
    def free_pages(self) -> int:
        return len(self.free)

    @property
    def pages_in_use(self) -> int:
        return (self.num_pages - 1) - len(self.free)

    def blocks_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page_size)

    def can_admit(self, prompt_tokens: int) -> bool:
        """Admission rule: free pages must cover the prompt blocks plus one
        decode page.  (The decode page is usually shared with the batch's
        current block, but one page of headroom keeps the rule local.)"""
        return self.free_pages >= self.blocks_for(prompt_tokens) + 1

    # ---------------------------------------------------------- transitions
    def map_block(self, row: int, block: int) -> int:
        """Map ``row``'s logical ``block`` to a fresh physical page."""
        if self.table[row, block] != 0:
            return int(self.table[row, block])
        if not self.free:
            raise RuntimeError(
                f"paged KV cache exhausted: 0 of {self.num_pages - 1} data "
                f"pages free while mapping block {block} of row {row}. "
                f"Size {self.sizing_knob} to the peak live-token count "
                f"(~batch * (prompt + budget) / page_size), or lower the "
                f"batch size."
            )
        page = self.free.pop()
        if page in self._ever_used:
            self.pages_reused += 1
        self._ever_used.add(page)
        self.table[row, block] = page
        self._owned[row].append(page)
        self.peak_pages_in_use = max(self.peak_pages_in_use, self.pages_in_use)
        self.dirty = True
        return page

    def ensure(self, row: int, start_slot: int, end_slot: int) -> None:
        """Map every block covering logical slots [start_slot, end_slot]
        for ``row`` — called before each chunk/rollout dispatch with the
        slot range the device program may write."""
        end_slot = min(end_slot, self.n_blocks * self.page_size - 1)
        for block in range(start_slot // self.page_size,
                           end_slot // self.page_size + 1):
            self.map_block(row, block)

    def admit_row(self, row: int, prompt_slots: int, cur: int) -> np.ndarray:
        """Fresh mapping for an admitted request: its prompt blocks
        [0, ceil(prompt_slots/ps)) plus the batch's current decode block.
        Returns the (n_blocks,) row table (the ``admit`` program's input).
        The row must have been freed (``free_row``) first."""
        if self._owned[row]:
            raise RuntimeError(f"row {row} still owns pages — free_row() "
                               f"before re-admitting")
        self.ensure(row, 0, max(prompt_slots - 1, 0))
        self.map_block(row, min(cur // self.page_size, self.n_blocks - 1))
        return self.table[row].copy()

    def detach_row(self, row: int) -> list[int]:
        """Unmap ``row`` WITHOUT returning its pages to the free list —
        the overlap pipeline's half of a deferred free: the row's table
        entries go to trash now (so the next table push stops the device
        writing there), but the physical pages stay out of circulation
        until the in-flight fence that may still read them retires
        (``InFlightLedger.defer_free`` holds them until then).  Returns
        the detached pages in ownership order."""
        pages = self._owned[row]
        self._owned[row] = []
        self.table[row] = 0
        if pages:
            self.dirty = True
        return pages

    def release_pages(self, pages: list[int]) -> None:
        """Second half of a deferred free: put detached ``pages`` back on
        the free list.  Guards against double-frees — a page must be
        neither already free nor owned by any row."""
        owned = {p for row in self._owned for p in row}
        for p in pages:
            if p in self.free or p in owned:
                raise RuntimeError(
                    f"double free of page {p}: already "
                    f"{'free' if p in self.free else 'owned'}"
                )
        self.free.extend(reversed(pages))

    def free_row(self, row: int) -> int:
        """Return all of ``row``'s pages to the free list (harvest time)
        and unmap the row.  Returns the number of pages freed."""
        pages = self.detach_row(row)
        self.release_pages(pages)
        return len(pages)

    def snapshot(self) -> np.ndarray:
        """The table to push to the device; marks the allocator clean.
        MUST be followed by an actual device update (the engine's
        ``put_page_table``) — skipping it would leave a freed row's stale
        mapping live on device, aliasing reused pages."""
        self.dirty = False
        return self.table

    # ------------------------------------------- page-native read indices
    #
    # The page-native attention path (kernels/paged_attention) reads K/V
    # through a COMPACTED per-row page list instead of the sparse (B, NB)
    # table: rank j of row b holds the j-th mapped logical block (ascending
    # logical order — required: the block scan must visit blocks in the
    # same order the ring comparator does).  The list is a pure function of
    # ``table``, so it can never drift from the admit/retract/free
    # bookkeeping above: every mutation goes through map_block / free_row,
    # and the engine re-derives the buckets at each dirty push.

    def mapped_counts(self) -> np.ndarray:
        """(batch,) mapped blocks per row — the kernel's per-row loop
        bound.  Retract never unmaps (a rewound row still owns its pages),
        so counts only change at map_block / free_row."""
        return (self.table != 0).sum(axis=1).astype(np.int32)

    @property
    def max_mapped_blocks(self) -> int:
        return int(self.mapped_counts().max(initial=0))

    def bucket_width(self, granule: int = 4) -> int:
        """Static bucket width covering every row's mapped count, rounded
        up to ``granule`` blocks so the jitted programs retrace every few
        pages of growth instead of every page."""
        need = max(self.max_mapped_blocks, 1)
        return min(-(-need // granule) * granule, self.n_blocks)

    def block_buckets(self, width: int) -> tuple[np.ndarray, np.ndarray,
                                                 np.ndarray]:
        """(pages, logical, counts): the compacted mapped-page list, padded
        to ``width`` ranks with the trash page (identity steps)."""
        B = self.table.shape[0]
        pages = np.zeros((B, width), np.int32)
        logical = np.zeros((B, width), np.int32)
        counts = np.zeros((B,), np.int32)
        for b in range(B):
            blocks = np.flatnonzero(self.table[b])        # ascending logical
            n = len(blocks)
            if n > width:
                raise ValueError(f"bucket width {width} < {n} mapped blocks "
                                 f"of row {b} — size with bucket_width()")
            pages[b, :n] = self.table[b, blocks]
            logical[b, :n] = blocks
            counts[b] = n
        return pages, logical, counts


class InFlightLedger:
    """Fence bookkeeping for the overlapped serve loop (pure host).

    The async pipeline (``serving.pipeline``) dispatches chunk N+1 before
    the host has harvested chunk N, so two chunk-boundary invariants the
    sync loop gets for free need explicit tracking:

    * **Deferred page frees** — a harvested row's KV pages may still be
      READ by the chunk already in flight (its page table was captured at
      dispatch).  ``defer_free`` detaches the pages from the allocator
      (table entries go to trash, so the *next* table push stops writes)
      but parks them on this ledger; they only re-enter the free list when
      the fence open at detach time retires.

    * **In-flight slot admission** — a slot freed at boundary N must not
      be re-admitted in a way that double-books it, and a row admitted
      DURING the tick that dispatched chunk F carries stale data in chunk
      F's snapshot (the old occupant's) — ``admitted_after(F)`` is the
      skip-set the boundary harvest uses to ignore those rows.

    Fences are dense integers: ``open_fence`` stamps each dispatched
    chunk, ``retire_fence`` retires them strictly in order (the pipeline
    harvests boundaries in dispatch order; out-of-order retirement is a
    pipeline bug and raises).  Lives next to the other pure-host
    bookkeeping so scheduler tests (incl. the hypothesis property suite)
    can drive it without a device.
    """

    def __init__(self):
        self.fence = 0        # last fence opened (0 = nothing dispatched)
        self.retired = 0      # last fence retired
        self._pending: list[tuple[int, PageAllocator, list[int]]] = []
        self._admitted_at: dict[int, int] = {}
        self._occupied: set[int] = set()
        self.pages_deferred = 0   # stat: pages that ever waited on a fence

    # -------------------------------------------------------------- fences
    @property
    def in_flight(self) -> bool:
        return self.fence > self.retired

    @property
    def quiescent(self) -> bool:
        return not self._pending and self.fence == self.retired

    def open_fence(self) -> int:
        self.fence += 1
        return self.fence

    def retire_fence(self, fence: int) -> None:
        if fence != self.retired + 1 or fence > self.fence:
            raise RuntimeError(
                f"fence {fence} retired out of order (last retired "
                f"{self.retired}, last opened {self.fence})"
            )
        self.retired = fence
        self._drain()

    def _drain(self) -> None:
        ready = [e for e in self._pending if e[0] <= self.retired]
        self._pending = [e for e in self._pending if e[0] > self.retired]
        for _, alloc, pages in ready:
            alloc.release_pages(pages)

    # --------------------------------------------------------- page frees
    def defer_free(self, alloc: PageAllocator, row: int) -> int:
        """Detach ``row``'s pages from ``alloc`` and hold them until the
        fence currently open retires (released immediately when nothing is
        in flight).  Returns the number of pages deferred."""
        pages = alloc.detach_row(row)
        if not pages:
            return 0
        self._pending.append((self.fence, alloc, pages))
        self.pages_deferred += len(pages)
        self._drain()
        return len(pages)

    # ----------------------------------------------------------- slot book
    def mark_admitted(self, slot: int) -> int:
        """Record ``slot`` (re)admitted at the current fence.  Raises if
        the ledger still considers the slot occupied — admitting into an
        in-flight slot is the bug the property tests hunt."""
        if slot in self._occupied:
            raise RuntimeError(f"slot {slot} admitted while still occupied")
        self._occupied.add(slot)
        self._admitted_at[slot] = self.fence
        return self.fence

    def mark_released(self, slot: int, fence: int) -> None:
        """Record ``slot`` released at boundary ``fence`` — which must
        already have retired (a release decided off a still-speculative
        snapshot would be a pipeline bug)."""
        if fence > self.retired:
            raise RuntimeError(
                f"slot {slot} released at un-retired fence {fence} "
                f"(last retired {self.retired})"
            )
        if slot not in self._occupied:
            raise RuntimeError(f"slot {slot} released but not occupied")
        self._occupied.discard(slot)

    def admitted_after(self, fence: int) -> set[int]:
        """Slots whose current occupant was admitted at or after ``fence``
        opened — their rows in fence ``fence``'s snapshot belong to the
        PREVIOUS occupant and must be skipped by the boundary harvest."""
        return {s for s, f in self._admitted_at.items() if f >= fence}
