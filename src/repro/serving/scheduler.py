"""Scheduler layer: slot allocation + admission policy for continuous
batching.

Middle of the three-layer serving stack (``request`` -> ``scheduler`` ->
``executor``).  Pure host-side Python — deliberately NO jax import: every
decision here is a list/deque operation over ``Request`` objects, so the
policy can be unit-tested without touching a device and swapped (priority
queues, per-tenant fairness, paged admission) without re-tracing any
program.

The policy is FIFO continuous batching: ``batch_size`` slots, a queue of
QUEUED requests, and the invariant that a slot freed by an early-exiting
sequence is refilled immediately (the executor's ``admit`` program merges
the freshly prefilled row in).  The scheduler also owns the cache-ring
capacity guard: ``cur`` advances one shared slot per batch-wide decode step
and never rewinds, so a wrap would silently overwrite live KV rows — we
refuse the admission instead.
"""
from __future__ import annotations

import math
from collections import deque
from typing import Iterator, Optional

from repro.serving.request import Request, RequestStatus


class SlotScheduler:
    """FIFO slot scheduler over a fixed-size continuous batch."""

    def __init__(self, requests: list[Request], batch_size: int, *,
                 capacity: int, budget: int):
        if batch_size < 1:
            raise ValueError("batch_size must be >= 1")
        self.requests = list(requests)
        self.queue: deque[Request] = deque(
            r for r in self.requests if r.status is RequestStatus.QUEUED
        )
        self.slots: list[Optional[Request]] = [None] * batch_size
        self.capacity = capacity
        self.budget = budget

    # ----------------------------------------------------------- admission
    def start_batch(self) -> list[Request]:
        """Admit the initial cohort: fill every slot from the queue (fewer
        requests than slots leaves the tail slots empty)."""
        cohort = []
        for slot in range(len(self.slots)):
            if not self.queue:
                break
            req = self.queue.popleft()
            req.admit(slot)
            self.slots[slot] = req
            cohort.append(req)
        return cohort

    def admit_next(self, slot: int) -> Optional[Request]:
        """Recycle a freed ``slot`` with the next queued request (None when
        the queue has drained).  The request comes back PREFILLING; the
        serve loop flips it to DECODING once its row is merged in."""
        if self.slots[slot] is not None:
            raise RuntimeError(f"slot {slot} is still occupied by request "
                               f"{self.slots[slot].rid}")
        if not self.queue:
            return None
        req = self.queue.popleft()
        req.admit(slot)
        self.slots[slot] = req
        return req

    # ------------------------------------------------------------- harvest
    def release(self, slot: int) -> Request:
        req = self.slots[slot]
        if req is None:
            raise RuntimeError(f"slot {slot} is already free")
        self.slots[slot] = None
        return req

    def finished_slots(self, active_mask) -> list[tuple[int, Request]]:
        """Slots whose resident request stopped decoding this chunk:
        ``active_mask`` is the host copy of ``ServeState.active``."""
        return [(s, r) for s, r in enumerate(self.slots)
                if r is not None and not bool(active_mask[s])]

    def bound(self) -> Iterator[tuple[int, Request]]:
        """(slot, request) pairs currently resident in the batch."""
        return ((s, r) for s, r in enumerate(self.slots) if r is not None)

    @property
    def running(self) -> bool:
        return any(r is not None for r in self.slots)

    @property
    def pending(self) -> int:
        return len(self.queue)

    # ------------------------------------------------------ capacity guard
    @staticmethod
    def required_capacity(prompt_width: int, n_requests: int,
                          batch_size: int, budget: int) -> int:
        """Cache slots needed for a batch-lifetime run of the ring cache:
        the shared ``cur`` pointer advances one slot per batch-wide decode
        step and never rewinds, so capacity must cover the prompt width
        plus every cohort's worst-case budget (one extra cohort of slack
        for admissions that straddle cohort boundaries).  The single
        sizing rule for every driver (CLI, benchmarks) of ``serve()``."""
        cohorts = math.ceil(n_requests / batch_size) + 1
        return prompt_width + cohorts * budget

    def check_capacity(self, used: int, when: str) -> None:
        """Refuse work that would wrap the shared cache ring.  ``used`` is
        the committed ring length (``int(state.cache['cur'])``)."""
        if used + self.budget > self.capacity:
            raise RuntimeError(
                f"EngineConfig.capacity={self.capacity} cannot hold "
                f"{when}: {used} slots committed + up to {self.budget} "
                f"decode steps would wrap the cache ring. Size capacity "
                f"to the batch-lifetime token count "
                f"(~prompt_width + ceil(n_requests / batch_size) * budget)."
            )
