"""Executor layer: every jitted device program of the serving stack.

This is the bottom of the three-layer serving architecture
(``request.py`` -> ``scheduler.py`` -> ``executor.py``; contracts and
diagram in docs/architecture.md):

  * ``request``   — per-request lifecycle state machine (host metadata),
  * ``scheduler`` — slot allocation + admission policy (pure host Python),
  * ``executor``  — the device programs those layers drive.

Contract: ALL jax lives at or below this layer (the request/scheduler
layers are host-only), and every program that mutates decode state follows
the consumes-state donation rule spelled out below — a caller that passes
a state to a donating program must treat that state as dead.

The executor owns the canonical single-token EAT step (``make_eat_step`` —
moved here from ``launch.serve_step`` so exactly one serve-step definition
exists in the tree) and builds every program the engine dispatches:

  prefill        prompt -> cache fill            (cache arg DONATED)
  decode_chunk   lax.while_loop of EAT steps     (ServeState DONATED)
  decode_chunk_snapshot  the chunk + a packed host-facing snapshot of the
                 harvest scalars in FRESH buffers (ServeState DONATED) —
                 the overlap pipeline's variant: the state is donated into
                 the next dispatch before the host reads anything, so the
                 host must never hold a reference into the state itself
  decode_step    one unmonitored step            (per-token baseline, no
                                                  donation: benchmarks call
                                                  it repeatedly on one state)
  probe          non-committing EAT evaluation   (never donated — the cache
                                                  must survive the probe)
  admit          slot recycling row-merge        (resident state DONATED)
  admit_paged    row-merge through a page table  (resident state DONATED)
  pack_paged     dense prefill -> page pool      (paged cache DONATED)
  rollout        forced answer generation        (NOT donated: callers keep
                                                  decoding from / re-rolling
                                                  the same live cache)
  retract        proxy-mode chunk reconciliation (ServeState DONATED)
  retract_lagged overlap-mode reconciliation one chunk late: only proxy-
                 stopped rows rewind; the rest pass through untouched
                                                  (ServeState DONATED)

The black-box (``monitor="proxy"``) tier adds a second program store:
``ProxyExecutor`` drives a *different* model that shadows the generator's
emitted token chunks (``observe_chunk`` — forced-input decode + the same
probe/monitor transition the self-EAT step runs) and owns its own KV cache,
page pool, and mesh context.  In proxy mode the generator executor builds
NO probe program and no monitored chunk — the black-box contract: no
generator logits feed the exit decision (audited by key inspection on
``_programs`` in tests/test_proxy_serve.py).

Programs are built once per ``(batch, variant)`` and cached.  With a mesh
in ``model.ctx`` (threaded from ``launch.mesh``) every program is jitted
with explicit ``in_shardings``/``out_shardings`` derived from
``sharding.partition.serve_state_pspecs`` / ``serving.cache.cache_pspecs``
/ ``param_pspecs`` — batch rows ride the data axis, heads/ffn ride the
model axis — so ``reason()``/``serve()`` run data- + tensor-parallel with
no host-side resharding between dispatches.  ``launch.dryrun`` lowers
``build_serve_step_program`` from this module, so the program the roofline
analyses cost out is the program the engine dispatches.

Donation contract: ``decode_chunk`` and ``admit`` consume the ServeState
they are passed (the KV cache is updated in place instead of being
re-allocated every chunk — ``input_output_alias`` in the compiled HLO,
asserted by ``tests/test_executor.py``).  Callers must treat a state they
hand to those programs as dead and continue from the returned state.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.eat import ProbeSpec, eval_eat
from repro.core.monitor import MonitorState, ReasoningMonitor
from repro.core.stopping import EATStopper
from repro.models.model import Model
from repro.serving.cache import (
    cache_pspecs,
    freeze_inactive_rows,
    merge_cache_row,
    merge_paged_row,
    pack_paged_cache,
)
from repro.serving.sampler import SamplerConfig, logprob_of, sample
from repro.sharding.partition import (
    param_pspecs,
    proxy_stream_pspecs,
    serve_snapshot_pspecs,
    serve_state_pspecs,
)


# --------------------------------------------------------------------------
# The program-store contract, exported for the static analyzer
# (src/repro/analysis/, docs/analysis.md).  tools/audit enumerates every
# builder across the full key matrix and checks these against the compiled
# artifacts — keep them in sync with the builders below.
# --------------------------------------------------------------------------

#: Every program family a serving run can dispatch, by key[0].
PROGRAM_FAMILIES = ("chunk", "decode", "prefill", "probe", "admit", "pack",
                    "retract", "rollout", "shadow")

#: family -> donated argument index (None = deliberately functional).  The
#: donation audit asserts input/output aliasing in the compiled artifact for
#: every donating program and its ABSENCE for the functional ones ("chunk"
#: keys carry an explicit donate flag at key[3]; the audit honours it).
DONATION_CONTRACT = {
    "chunk": 1,       # ServeState
    "decode": None,   # benchmarks re-time it against one fixed state
    "prefill": 4,     # the freshly allocated cache
    "probe": None,    # the probe must not consume the live cache
    "admit": 0,       # the resident batch state (ring AND paged variants)
    "pack": 0,        # the paged template
    "retract": 0,     # ServeState
    "rollout": None,  # functional read of a live cache
    "shadow": 1,      # the proxy's ServeState
}

#: Families waived from the program-key completeness lint, with the reason.
#: A waiver is a claim that the un-keyed inputs cannot silently change the
#: traced program: prefill always runs over a dense cache (paged serves
#: prefill dense, then ``pack_paged`` scatters), so the cache kind / decode
#: attention impl never reach its graph, and a pytree-structure change in
#: the cache argument retraces (or fails loudly on a mesh) rather than
#: serving a stale program.
KEY_EXEMPT = {
    "prefill": "dense prompt prefill; cache kind/attn impl never reach the "
               "traced graph, structure changes retrace",
}


def cache_kind(cache: dict) -> str:
    """'paged' when the cache routes K/V through a page table, else 'ring'.
    Program-cache keys include this: the two kinds have different pytree
    structures, so their jitted programs (and mesh in/out shardings) are
    built separately.  Executor keys additionally carry the decode-attention
    impl (``Executor._kind``): a "paged+xla" program reads K/V through the
    compacted page list, a plain "paged" one gathers — different traced
    graphs even over the same pytree structure."""
    return "paged" if "page_table" in cache else "ring"


def mesh_ns(ctx, spec: P) -> NamedSharding:
    """One PartitionSpec -> NamedSharding on the ctx mesh."""
    return NamedSharding(ctx.mesh, spec)


def mesh_shardings(ctx, spec_tree):
    """PartitionSpec pytree -> NamedSharding pytree on the ctx mesh — the
    single spec->sharding hop for every executor program (the Executor
    methods and the dry-run's ``build_serve_step_program`` both route
    through here, so the lowered and the dispatched programs cannot drift
    in how specs become shardings)."""
    return jax.tree_util.tree_map(lambda s: mesh_ns(ctx, s), spec_tree)


def positions_for(cfg, pos1d):
    """Model-facing positions from 1-D positions: mrope configs broadcast
    to the 3-section layout, everyone else passes through.  THE single
    definition — prefill (engine.start), the EAT step, and rollouts must
    agree or cached and probed positions silently diverge."""
    if cfg.mrope_sections:
        return jnp.broadcast_to(pos1d[..., None], pos1d.shape + (3,))
    return pos1d


class ServeState(NamedTuple):
    """Device-resident batched decode state (one row per slot)."""

    cache: dict
    rng: jax.Array
    active: jax.Array          # (B,) still reasoning
    next_pos: jax.Array        # (B,) next token position (left-pad aware)
    last_token: jax.Array      # (B,)
    n_reasoning: jax.Array     # (B,) reasoning tokens generated
    monitor: MonitorState
    ended_think: jax.Array     # (B,) emitted </think> naturally
    out_tokens: jax.Array      # (B, T_buf) generated reasoning tokens
    out_len: jax.Array         # (B,)


#: Row order of the packed (len(SNAP_ROWS), B) int32 block of a chunk
#: snapshot (``Executor.decode_chunk_snapshot``) — the overlap pipeline
#: indexes the host copy by position in this tuple.  ``cur`` is the cache's
#: shared ring pointer broadcast per row so the whole int snapshot is one
#: fused buffer.
SNAP_ROWS = ("active", "n_reasoning", "out_len", "ended_think", "stop_flag",
             "n_evals", "cur")


# --------------------------------------------------------------------------
# The canonical single-token EAT-monitored decode step — ONE program, every
# driver: the engine's device-resident chunks scan it, the dry-runs lower it.
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ServeStepConfig:
    window: int = 0
    probe: ProbeSpec = ProbeSpec((1, 6))        # </think> + "final answer:" prefix
    stopper: EATStopper = EATStopper(alpha=0.2, delta=1e-3)
    sampler: SamplerConfig = SamplerConfig()
    with_probe: bool = True
    # §Perf: fuse the probe into the decode forward (one weight pass per
    # step instead of two; see Model.decode_and_probe)
    fused_probe: bool = False


def serve_monitor(scfg: ServeStepConfig) -> ReasoningMonitor:
    """The dry-run's evaluation schedule: probe every token, no warmup —
    the most expensive (upper-bound) configuration of the monitored step."""
    return ReasoningMonitor(stopper=scfg.stopper, probe=scfg.probe,
                            schedule="every_n", every_n=1, min_evals=0)


def make_eat_step(
    model: Model,
    monitor: ReasoningMonitor | None,
    sampler: SamplerConfig,
    *,
    window: int | None = None,
    probe_cond: bool = True,
    fused_probe: bool = False,
):
    """Build ``step(params, cache, token, pos1d, mon, active, rng)``
    -> ``(next_token, cache, mon, stop, rng)``.

    token/pos1d: (B,1); mon: MonitorState; active: (B,) bool.  ``stop`` is
    the latched per-sequence exit mask (``mon.stop_flag``).

    ``probe_cond=True`` wraps the probe+update in ``lax.cond`` on
    ``(due & active).any()`` so chunks where no sequence hits an evaluation
    point pay zero probe FLOPs (the engine's sparse-schedule case);
    ``probe_cond=False`` probes unconditionally (the dry-run's every-token
    schedule, where the cond would always take the probe branch anyway).
    """
    cfg = model.cfg

    def _positions(pos1d):
        return positions_for(cfg, pos1d)

    def step(params, cache, token, pos1d, mon: MonitorState, active, rng):
        if monitor is not None and fused_probe:
            B = token.shape[0]
            m = len(monitor.probe)
            probe_toks = jnp.broadcast_to(
                jnp.asarray(monitor.probe.tokens, jnp.int32), (B, m)
            )
            pos_all = pos1d[:, :1] + jnp.arange(1 + m, dtype=jnp.int32)[None]
            logits, eat, cache = model.decode_and_probe(
                params, token, _positions(pos_all), pos_all, cache, probe_toks,
                window=window,
            )
            rng, sub = jax.random.split(rng)
            nxt = sample(sub, logits[:, -1], cfg.vocab, sampler)
            mon = monitor.update(mon, eat, monitor.due(mon, nxt), active)
            return nxt, cache, mon, mon.stop_flag, rng

        logits, cache = model.decode_step(
            params, token, _positions(pos1d), pos1d, cache, window=window
        )
        rng, sub = jax.random.split(rng)
        nxt = sample(sub, logits[:, -1], cfg.vocab, sampler)
        if monitor is None:
            return nxt, cache, mon, jnp.zeros(nxt.shape, bool), rng

        next_pos = pos1d[:, -1] + 1
        eat_fn = lambda: eval_eat(model, params, cache, monitor.probe, next_pos)  # noqa: E731
        mon = monitor.observe(mon, eat_fn, nxt, active, lazy=probe_cond)
        return nxt, cache, mon, mon.stop_flag, rng

    return step


def make_shadow_step(model: Model, monitor: ReasoningMonitor):
    """Build the proxy-side forced-token EAT step
    ``step(params, cache, tok_in, tok_out, next_pos, mon, valid)``
    -> ``(cache, mon, next_pos)``.

    The mirror of ``make_eat_step`` for a model that does not choose the
    tokens: ``tok_in`` (B,1) is the token the GENERATOR fed at this step
    (committed into the proxy cache), ``tok_out`` (B,) the token the
    generator emitted (the monitor's due-check input), ``valid`` (B,) the
    mask of rows still consuming the stream.  Invalid rows write at
    position -1 (masked) and their monitor state freezes — exactly the
    inactive-row handling of the self-EAT step, so a proxy running the
    generator's own params reproduces the self-EAT EMA trajectory
    bit-for-bit (tests/test_proxy_serve.py).
    """
    cfg = model.cfg

    def step(params, cache, tok_in, tok_out, next_pos, mon: MonitorState,
             valid):
        pos1d = jnp.where(valid, next_pos, -1)[:, None]
        _, new_cache = model.decode_step(
            params, tok_in, positions_for(cfg, pos1d), pos1d, cache
        )
        if cfg.arch_type in ("ssm", "hybrid"):
            new_cache = freeze_inactive_rows(new_cache, cache, valid)
        new_pos = next_pos + valid.astype(jnp.int32)
        eat_fn = lambda: eval_eat(model, params, new_cache, monitor.probe, new_pos)  # noqa: E731
        mon = monitor.observe(mon, eat_fn, tok_out, valid, lazy=True)
        return new_cache, mon, new_pos

    return step


def build_serve_step_program(model: Model, scfg: ServeStepConfig,
                             cache_struct, params_struct):
    """The decode-shape dry-run program: ONE every-token EAT step, jitted
    with explicit shardings and the cache donated — the exact program shape
    ``launch.dryrun`` lowers and costs out.

    Returns ``(jitted_fn, mon_struct)``; call as
    ``jitted_fn(params, cache, token, pos1d, mon, rng)``.
    """
    ctx, cfg = model.ctx, model.cfg
    monitor = serve_monitor(scfg) if scfg.with_probe else None
    step = make_eat_step(
        model, monitor, scfg.sampler, window=scfg.window,
        probe_cond=False, fused_probe=scfg.fused_probe,
    )

    def serve_step(params, cache, token, pos1d, mon: MonitorState, rng):
        """token/pos1d: (B,1).  Returns (next_token, cache, mon, stop, rng)."""
        active = jnp.ones(token.shape[:1], bool)
        return step(params, cache, token, pos1d, mon, active, rng)

    B = cache_struct["pos"].shape[0]
    mon_struct = jax.eval_shape(lambda: serve_monitor(scfg).init(B))
    if ctx.mesh is None:
        return jax.jit(serve_step, donate_argnums=1), mon_struct

    b = ctx.batch_entry_for(B)
    in_sh = (
        mesh_shardings(ctx, param_pspecs(params_struct, cfg, ctx)),
        mesh_shardings(ctx, cache_pspecs(cfg, ctx, cache_struct)),
        mesh_ns(ctx, P(b, None)),
        mesh_ns(ctx, P(b, None)),
        jax.tree_util.tree_map(lambda _: mesh_ns(ctx, P(b)), mon_struct),
        mesh_ns(ctx, P()),
    )
    return jax.jit(serve_step, in_shardings=in_sh, donate_argnums=1), mon_struct


def build_stream_monitor_programs(model: Model, probe: ProbeSpec):
    """Jitted programs for the host-streaming ``ProxyMonitor``
    (serving/proxy.py): ``(consume, probe_fn, prefill)``.

    ``consume(params, cache, tokens, next_pos)`` prefills an arriving chunk
    into the monitor's cache; ``probe_fn(params, cache, next_pos)`` is the
    non-committing EAT evaluation; ``prefill`` is the plain prompt prefill
    (re-traced per prompt shape by jit's signature cache).  Built here so
    proxy.py stays a host-orchestration layer — the executor module is the
    only place in ``serving/`` that constructs jitted programs (the
    layering contract checked by tools/audit)."""

    def _positions(pos1d):
        return positions_for(model.cfg, pos1d)

    @jax.jit
    def consume(params, cache, tokens, next_pos):
        B, m = tokens.shape
        pos1d = next_pos[:, None] + jnp.arange(m, dtype=jnp.int32)[None]
        _, cache = model.prefill(params, tokens, _positions(pos1d), pos1d,
                                 cache)
        return cache, next_pos + m

    @jax.jit
    def probe_fn(params, cache, next_pos):
        return eval_eat(model, params, cache, probe, next_pos)

    @jax.jit
    def prefill(params, prompts, positions, pos1d, cache):
        return model.prefill(params, prompts, positions, pos1d, cache)

    return consume, probe_fn, prefill


# --------------------------------------------------------------------------
# Executor: the engine-facing program store
# --------------------------------------------------------------------------

class Executor:
    """Builds and caches every jitted program ``ReasoningEngine`` dispatches.

    One instance per ``(model, EngineConfig, monitor)``; programs are built
    lazily per batch size (shardings depend on whether the batch divides the
    data axis) and cached for the executor's lifetime.
    """

    def __init__(self, model: Model, params, ecfg, monitor: ReasoningMonitor):
        self.model = model
        self.ecfg = ecfg
        self.monitor = monitor
        self.ctx = model.ctx
        self.cfg = model.cfg
        self._programs: dict = {}
        self._param_sh = None
        if self.ctx.mesh is not None:
            self._param_sh = self._sh(param_pspecs(params, self.cfg, self.ctx))
        self._step_mon = make_eat_step(model, monitor, ecfg.sampler,
                                       probe_cond=True)
        self._step_plain = make_eat_step(model, None, ecfg.sampler)

    def _kind(self, cache: dict) -> str:
        """``cache_kind`` + the model's decode-attention impl — the program
        key component the ``--attn-impl`` knob threads through, so a
        page-native program can never be served from a gather key (or vice
        versa) even if two executors share a program store in a test."""
        kind = cache_kind(cache)
        impl = self.model.paged_attn_impl
        return kind if impl == "gather" else f"{kind}+{impl}"

    # ---------------------------------------------------------- shardings
    def _ns(self, spec: P):
        return mesh_ns(self.ctx, spec)

    def _sh(self, spec_tree):
        return mesh_shardings(self.ctx, spec_tree)

    def _batch_entry(self, B: int):
        return self.ctx.batch_entry_for(B)

    def _state_sh(self, state: ServeState):
        return self._sh(serve_state_pspecs(self.cfg, self.ctx, state))

    def shard_params(self, params):
        """Place the parameter pytree on the mesh once, so per-dispatch
        ``in_shardings`` never trigger a host->device re-transfer."""
        if self.ctx.mesh is None:
            return params
        return jax.device_put(params, self._param_sh)

    # ---------------------------------------------------------- programs
    def _advance(self, params, state: ServeState, budget, step_fn) -> ServeState:
        """One monitored decode step + engine bookkeeping, all masked."""
        cfg, ecfg = self.cfg, self.ecfg
        tok = state.last_token[:, None]
        # inactive rows still ride through the batched step, but their
        # KV write must be invisible: pos=-1 keeps the duplicate-position
        # entry out of every later attention mask (q_pos >= kv_pos >= 0)
        pos1d = jnp.where(state.active, state.next_pos, -1)[:, None]
        nxt, cache, mon, stop, rng = step_fn(
            params, state.cache, tok, pos1d, state.monitor,
            state.active, state.rng,
        )
        if cfg.arch_type in ("ssm", "hybrid"):
            cache = freeze_inactive_rows(cache, state.cache, state.active)
        nxt = jnp.where(state.active, nxt, ecfg.pad_id)
        ended = state.ended_think | (state.active & (nxt == ecfg.end_think_id))
        out_tokens = state.out_tokens.at[
            jnp.arange(nxt.shape[0]), state.out_len
        ].set(nxt)
        inc = state.active.astype(jnp.int32)
        n_reasoning = state.n_reasoning + inc
        over = n_reasoning >= budget
        return ServeState(
            cache=cache,
            rng=rng,
            active=state.active & ~stop & ~ended & ~over,
            next_pos=state.next_pos + inc,
            last_token=nxt,
            n_reasoning=n_reasoning,
            monitor=mon,
            ended_think=ended,
            out_tokens=out_tokens,
            out_len=state.out_len + inc,
        )

    def chunk_program(self, state: ServeState, use_monitor: bool,
                      donate: bool = True):
        # ``donate=False`` exists ONLY for the donation audit
        # (tests/test_executor.py), which A/Bs the compiled memory stats of
        # the same program with and without the in-place cache alias.
        B = int(state.active.shape[0])
        key = ("chunk", B, use_monitor, donate, self._kind(state.cache))
        if key not in self._programs:
            step_fn = self._step_mon if use_monitor else self._step_plain

            def chunk(params, st: ServeState, budget, chunk_len):
                def cond(carry):
                    i, s = carry
                    return (i < chunk_len) & s.active.any()

                def body(carry):
                    i, s = carry
                    return i + 1, self._advance(params, s, budget, step_fn)

                _, st = jax.lax.while_loop(
                    cond, body, (jnp.zeros((), jnp.int32), st)
                )
                return st

            dn = (1,) if donate else ()
            if self.ctx.mesh is None:
                jitted = jax.jit(chunk, donate_argnums=dn)
            else:
                ssh = self._state_sh(state)
                jitted = jax.jit(
                    chunk,
                    in_shardings=(self._param_sh, ssh, self._ns(P()),
                                  self._ns(P())),
                    out_shardings=ssh,
                    donate_argnums=dn,
                )
            self._programs[key] = jitted
        return self._programs[key]

    def decode_chunk(self, params, state: ServeState, budget, chunk_len,
                     *, use_monitor: bool = True) -> ServeState:
        """Advance up to ``chunk_len`` monitored tokens in ONE dispatch
        (``lax.while_loop`` over the EAT step).  DONATES ``state``."""
        return self.chunk_program(state, use_monitor)(
            params, state, budget, chunk_len
        )

    # ----------------------------------------------- overlap-mode programs
    #
    # The async pipeline (serving/pipeline.py) dispatches chunk N+1 before
    # the host has read anything of chunk N, and the chunk donates its
    # ServeState into that next dispatch — so a host reference into any
    # state buffer would be invalidated mid-read.  Every host-facing value
    # therefore comes back as a SEPARATE snapshot: ``_snapshot_of`` routes
    # each field through stack/concatenate, whose output shapes differ from
    # every state field, so XLA can never alias a snapshot buffer to an
    # output that a later dispatch donates away.

    def _snapshot_of(self, st: ServeState) -> dict:
        B = st.active.shape[0]
        cur = jnp.broadcast_to(
            jnp.asarray(st.cache["cur"], jnp.int32).reshape(()), (B,))
        ints = jnp.stack([
            st.active.astype(jnp.int32),
            st.n_reasoning.astype(jnp.int32),
            st.out_len.astype(jnp.int32),
            st.ended_think.astype(jnp.int32),
            st.monitor.stop_flag.astype(jnp.int32),
            st.monitor.n_evals.astype(jnp.int32),
            cur,
        ], 0)
        var = self.monitor.stopper.debiased_var(st.monitor.stop_state)
        toks = jnp.concatenate([st.out_tokens, st.out_len[:, None]], 1)
        return {"ints": ints, "var": var.astype(jnp.float32), "tokens": toks}

    def chunk_snapshot_program(self, state: ServeState, use_monitor: bool):
        B = int(state.active.shape[0])
        key = ("chunk", B, use_monitor, True, self._kind(state.cache), "snap")
        if key not in self._programs:
            step_fn = self._step_mon if use_monitor else self._step_plain

            def chunk(params, st: ServeState, budget, chunk_len):
                def cond(carry):
                    i, s = carry
                    return (i < chunk_len) & s.active.any()

                def body(carry):
                    i, s = carry
                    return i + 1, self._advance(params, s, budget, step_fn)

                _, st = jax.lax.while_loop(
                    cond, body, (jnp.zeros((), jnp.int32), st)
                )
                return st, self._snapshot_of(st)

            if self.ctx.mesh is None:
                jitted = jax.jit(chunk, donate_argnums=(1,))
            else:
                ssh = self._state_sh(state)
                jitted = jax.jit(
                    chunk,
                    in_shardings=(self._param_sh, ssh, self._ns(P()),
                                  self._ns(P())),
                    out_shardings=(ssh,
                                   self._sh(serve_snapshot_pspecs(self.ctx,
                                                                  B))),
                    donate_argnums=(1,),
                )
            self._programs[key] = jitted
        return self._programs[key]

    def decode_chunk_snapshot(self, params, state: ServeState, budget,
                              chunk_len, *, use_monitor: bool = True
                              ) -> tuple[ServeState, dict]:
        """``decode_chunk`` plus the packed harvest snapshot the overlap
        pipeline reads one boundary late: ``(state, {ints, var, tokens})``
        where ``ints`` is the (len(SNAP_ROWS), B) int32 block (row order
        ``SNAP_ROWS``), ``var`` the debiased EMA variance the traces record,
        and ``tokens`` the (B, T+1) out_tokens copy (last column = out_len).
        DONATES ``state``; the snapshot buffers are fresh and stay valid
        after the state is donated into the next dispatch."""
        return self.chunk_snapshot_program(state, use_monitor)(
            params, state, budget, chunk_len
        )

    def decode_program(self, state: ServeState):
        key = ("decode", int(state.active.shape[0]), self._kind(state.cache))
        if key not in self._programs:
            def fn(params, st: ServeState):
                no_budget = jnp.asarray(jnp.iinfo(jnp.int32).max, jnp.int32)
                return self._advance(params, st, no_budget, self._step_plain)

            if self.ctx.mesh is None:
                jitted = jax.jit(fn)
            else:
                ssh = self._state_sh(state)
                jitted = jax.jit(fn, in_shardings=(self._param_sh, ssh),
                                 out_shardings=ssh)
            self._programs[key] = jitted
        return self._programs[key]

    def decode_step(self, params, state: ServeState) -> ServeState:
        """One unmonitored decode step — ``_advance`` with no budget.  The
        per-token baseline for ``benchmarks/engine_throughput.py`` and unit
        tests (so the two paths can never diverge).  No donation: the
        benchmarks re-time it against one fixed state."""
        return self.decode_program(state)(params, state)

    def prefill_program(self, cache, B: int, has_frames: bool = False,
                        has_image: bool = False):
        key = ("prefill", B, has_frames, has_image)
        if key not in self._programs:
            model = self.model

            if has_frames:
                def fn(params, tokens, positions, pos1d, cache, frames):
                    return model.prefill(params, tokens, positions, pos1d,
                                         cache, frames=frames)
            elif has_image:
                def fn(params, tokens, positions, pos1d, cache, image_embeds):
                    return model.prefill(params, tokens, positions, pos1d,
                                         cache, image_embeds=image_embeds)
            else:
                def fn(params, tokens, positions, pos1d, cache):
                    return model.prefill(params, tokens, positions, pos1d,
                                         cache)

            if self.ctx.mesh is None:
                jitted = jax.jit(fn, donate_argnums=4)
            else:
                b = self._batch_entry(B)
                pos_spec = (P(b, None, None) if self.cfg.mrope_sections
                            else P(b, None))
                in_sh = [
                    self._param_sh,
                    self._ns(P(b, None)),
                    self._ns(pos_spec),
                    self._ns(P(b, None)),
                    self._sh(cache_pspecs(self.cfg, self.ctx, cache)),
                ]
                if has_frames or has_image:
                    in_sh.append(self._ns(P(b, None, None)))
                jitted = jax.jit(fn, in_shardings=tuple(in_sh),
                                 donate_argnums=4)
            self._programs[key] = jitted
        return self._programs[key]

    def prefill(self, params, tokens, positions, pos1d, cache, *,
                frames=None, image_embeds=None):
        """Prompt prefill; returns (hidden, cache).  DONATES ``cache`` (the
        engine always hands it a freshly allocated one)."""
        prog = self.prefill_program(cache, int(tokens.shape[0]),
                                    frames is not None,
                                    image_embeds is not None)
        extras = [x for x in (frames, image_embeds) if x is not None]
        return prog(params, tokens, positions, pos1d, cache, *extras)

    def probe_program(self, cache, B: int):
        key = ("probe", B, self._kind(cache))
        if key not in self._programs:
            model, monitor = self.model, self.monitor

            def fn(params, cache, next_pos):
                return eval_eat(model, params, cache, monitor.probe, next_pos)

            if self.ctx.mesh is None:
                jitted = jax.jit(fn)
            else:
                b = self._batch_entry(B)
                jitted = jax.jit(fn, in_shardings=(
                    self._param_sh,
                    self._sh(cache_pspecs(self.cfg, self.ctx, cache)),
                    self._ns(P(b)),
                ))
            self._programs[key] = jitted
        return self._programs[key]

    def probe(self, params, cache, next_pos):
        """Non-committing EAT probe over the live cache.  Never donated —
        the whole point is that the cache survives the evaluation."""
        return self.probe_program(cache, int(next_pos.shape[0]))(
            params, cache, next_pos
        )

    def admit_program(self, state: ServeState, one: ServeState):
        key = ("admit", int(state.active.shape[0]))
        if key not in self._programs:
            def fn(state: ServeState, one: ServeState, slot) -> ServeState:
                def put(big, small):
                    return big.at[slot].set(small[0])

                return ServeState(
                    cache=merge_cache_row(state.cache, one.cache, slot),
                    rng=state.rng,
                    active=put(state.active, one.active),
                    next_pos=put(state.next_pos, one.next_pos),
                    last_token=put(state.last_token, one.last_token),
                    n_reasoning=put(state.n_reasoning, one.n_reasoning),
                    monitor=jax.tree_util.tree_map(put, state.monitor,
                                                   one.monitor),
                    ended_think=put(state.ended_think, one.ended_think),
                    out_tokens=put(state.out_tokens, one.out_tokens),
                    out_len=put(state.out_len, one.out_len),
                )

            if self.ctx.mesh is None:
                jitted = jax.jit(fn, donate_argnums=0)
            else:
                ssh = self._state_sh(state)
                jitted = jax.jit(
                    fn,
                    in_shardings=(ssh, self._state_sh(one), self._ns(P())),
                    out_shardings=ssh,
                    donate_argnums=0,
                )
            self._programs[key] = jitted
        return self._programs[key]

    def admit(self, state: ServeState, one: ServeState, slot) -> ServeState:
        """Recycle a batch slot: overwrite row ``slot`` of every per-
        sequence array (and the cache row, see ``merge_cache_row``) with
        the freshly-prefilled single-sequence state ``one``.  One fused
        dispatch; ``slot`` is traced so admissions into different slots
        share the compilation.  DONATES ``state`` (the resident batch)."""
        return self.admit_program(state, one)(
            state, one, jnp.asarray(slot, jnp.int32)
        )

    # ------------------------------------------------------ paged programs
    def pack_paged_program(self, paged_cache: dict, dense_cache: dict):
        B = int(paged_cache["pos"].shape[0])
        C_pre = int(dense_cache["pos"].shape[1])
        key = ("pack", B, C_pre)
        if key not in self._programs:
            if self.ctx.mesh is None:
                jitted = jax.jit(pack_paged_cache, donate_argnums=0)
            else:
                jitted = jax.jit(
                    pack_paged_cache,
                    in_shardings=(
                        self._sh(cache_pspecs(self.cfg, self.ctx, paged_cache)),
                        self._sh(cache_pspecs(self.cfg, self.ctx, dense_cache)),
                        self._ns(P(None, None)),
                    ),
                    out_shardings=self._sh(
                        cache_pspecs(self.cfg, self.ctx, paged_cache)),
                    donate_argnums=0,
                )
            self._programs[key] = jitted
        return self._programs[key]

    def pack_paged(self, paged_cache: dict, dense_cache: dict, table) -> dict:
        """Scatter a freshly prefilled dense cache into an empty paged
        cache (serve()-start conversion).  DONATES ``paged_cache`` — the
        pools are updated in place, same contract as every other
        cache-consuming program."""
        return self.pack_paged_program(paged_cache, dense_cache)(
            paged_cache, dense_cache, jnp.asarray(table, jnp.int32)
        )

    def admit_paged_program(self, state: ServeState, one: ServeState):
        key = ("admit", int(state.active.shape[0]), "paged",
               int(one.cache["pos"].shape[1]))
        if key not in self._programs:
            def fn(state: ServeState, one: ServeState, slot,
                   row_table) -> ServeState:
                def put(big, small):
                    return big.at[slot].set(small[0])

                return ServeState(
                    cache=merge_paged_row(state.cache, one.cache, slot,
                                          row_table),
                    rng=state.rng,
                    active=put(state.active, one.active),
                    next_pos=put(state.next_pos, one.next_pos),
                    last_token=put(state.last_token, one.last_token),
                    n_reasoning=put(state.n_reasoning, one.n_reasoning),
                    monitor=jax.tree_util.tree_map(put, state.monitor,
                                                   one.monitor),
                    ended_think=put(state.ended_think, one.ended_think),
                    out_tokens=put(state.out_tokens, one.out_tokens),
                    out_len=put(state.out_len, one.out_len),
                )

            if self.ctx.mesh is None:
                jitted = jax.jit(fn, donate_argnums=0)
            else:
                ssh = self._state_sh(state)
                jitted = jax.jit(
                    fn,
                    in_shardings=(ssh, self._state_sh(one), self._ns(P()),
                                  self._ns(P(None))),
                    out_shardings=ssh,
                    donate_argnums=0,
                )
            self._programs[key] = jitted
        return self._programs[key]

    def admit_paged(self, state: ServeState, one: ServeState, slot,
                    row_table) -> ServeState:
        """Paged-cache slot recycling: like ``admit``, but the cache merge
        routes the admitted prompt K/V through ``row_table`` (the
        allocator's fresh page mapping for the slot — prompt blocks plus
        one decode page).  ``slot`` and ``row_table`` are traced, so
        admissions into different slots share the compilation.  DONATES
        ``state``."""
        return self.admit_paged_program(state, one)(
            state, one, jnp.asarray(slot, jnp.int32),
            jnp.asarray(row_table, jnp.int32)
        )

    def put_page_table(self, state: ServeState, table,
                       blocks: tuple | None = None) -> ServeState:
        """Swap the host allocator's page table — and, in page-native mode,
        its compacted mapped-page buckets ``(pages, logical, counts)`` —
        into the state (replicated on the mesh).  Host->device upload of a
        few KB of int32 — called once per chunk boundary, never inside a
        jitted program.  A bucket-width change simply retraces the next
        dispatch (the NamedShardings are shape-agnostic)."""
        from repro.serving.cache import blocks_arrays

        def rep(x, spec):
            dev = jnp.asarray(x, jnp.int32)
            if self.ctx.mesh is not None:
                dev = jax.device_put(dev, self._ns(spec))
            return dev

        cache = dict(state.cache)
        cache["page_table"] = rep(table, P(None, None))
        if blocks is not None:
            pages, logical, counts = blocks
            dev = blocks_arrays(pages, logical, counts)
            dev = {"pages": rep(dev["pages"], P(None, None)),
                   "logical": rep(dev["logical"], P(None, None)),
                   "count": rep(dev["count"], P(None))}
            cache["blocks"] = dev
        return state._replace(cache=cache)

    def ensure_chunk_pages(self, alloc, state: ServeState, slots, span: int,
                           *, tail: int = 0, budget: int | None = None,
                           cur: int | None = None, n_reasoning=None,
                           slack: int = 0) -> ServeState:
        """Map (and push) pages covering the next ``span`` logical slots
        for every slot in ``slots`` before a writing dispatch — THE page-
        sizing rule for a chunk, shared by the generator loop and the
        proxy tier's shadow decode.  With ``budget`` the span is clamped
        per row to the tokens it can still emit plus the probe ``tail``
        (a row never decodes past its budget, so pages past it would be
        reserved-but-never-written — enough waste to break the documented
        pool sizing rule when the chunk exceeds the remaining budget).
        The table upload is skipped while the mapping is unchanged
        (steady decode inside a block).

        ``cur`` / ``n_reasoning`` override the host reads of the state's
        ring pointer and per-row counts: the overlap pipeline passes its
        mirrors from the last retired fence so mapping never blocks on an
        in-flight chunk.  Mirrors lag the device by up to one dispatched
        chunk, so the pipeline also passes ``slack`` (extra leading slots,
        mapped on top of the per-row clamp) to cover the writes of the
        not-yet-harvested dispatch; pessimistic by at most one chunk of
        pages per row."""
        cur0 = int(state.cache["cur"]) if cur is None else int(cur)
        n_r = None
        if budget is not None:
            n_r = (np.asarray(state.n_reasoning) if n_reasoning is None
                   else np.asarray(n_reasoning))
        for s in slots:
            sp = span
            if n_r is not None:
                left = max(1, budget - int(n_r[s]))
                sp = min(span, left + tail)
            alloc.ensure(s, cur0, cur0 + slack + sp)
        if not alloc.dirty:
            return state
        # page-native caches carry the compacted read index: re-derive it
        # from the (just-mutated) table so the two can never drift
        blocks = (alloc.block_buckets(alloc.bucket_width())
                  if "blocks" in state.cache else None)
        return self.put_page_table(state, alloc.snapshot(), blocks)

    def retract_program(self, state: ServeState):
        key = ("retract", int(state.active.shape[0]),
               self._kind(state.cache))
        if key not in self._programs:
            ecfg = self.ecfg

            def fn(state: ServeState, new_n, pmon: MonitorState) -> ServeState:
                overshoot = state.n_reasoning - new_n
                next_pos = state.next_pos - overshoot
                cache = dict(state.cache)
                cache["pos"] = jnp.where(
                    cache["pos"] >= next_pos[:, None], -1, cache["pos"]
                )
                cols = jnp.arange(state.out_tokens.shape[1],
                                  dtype=jnp.int32)[None]
                keep = cols < new_n[:, None]
                last = jnp.take_along_axis(
                    state.out_tokens, (new_n - 1)[:, None], 1)[:, 0]
                # re-derive the </think> latch over the KEPT tokens only: a
                # natural end the generator hit past the proxy's stop point
                # never happened in self-EAT terms
                ended = (jnp.where(keep, state.out_tokens, -1)
                         == ecfg.end_think_id).any(-1)
                return ServeState(
                    cache=cache,
                    rng=state.rng,
                    active=state.active & ~pmon.stop_flag,
                    next_pos=next_pos,
                    last_token=last,
                    n_reasoning=new_n,
                    monitor=pmon,
                    ended_think=ended,
                    out_tokens=jnp.where(keep, state.out_tokens, ecfg.pad_id),
                    out_len=new_n,
                )

            if self.ctx.mesh is None:
                jitted = jax.jit(fn, donate_argnums=0)
            else:
                ssh = self._state_sh(state)
                b = self._batch_entry(int(state.active.shape[0]))
                jitted = jax.jit(
                    fn,
                    in_shardings=(
                        ssh,
                        self._ns(P(b)),
                        jax.tree_util.tree_map(lambda _: self._ns(P(b)),
                                               state.monitor),
                    ),
                    out_shardings=ssh,
                    donate_argnums=0,
                )
            self._programs[key] = jitted
        return self._programs[key]

    def retract(self, state: ServeState, new_n, pmon: MonitorState
                ) -> ServeState:
        """Proxy-mode chunk-boundary reconciliation: rewind every row to the
        proxy's exit decision and sync the proxy monitor into the state.

        In ``monitor="proxy"`` serving the generator decodes whole chunks
        blind (no inline probe), so a row the proxy stopped at emitted-token
        count ``new_n[b] < n_reasoning[b]`` has overshot: extra tokens in
        ``out_tokens``, extra KV committed past the exit position.  This
        program truncates the token buffer back to ``new_n``, rewinds
        ``next_pos``/``n_reasoning``/``out_len``, position-masks the
        overshoot KV (``pos >= new next_pos`` -> -1, slot-agnostic so it
        works for ring AND paged caches — masked slots contribute exact
        zeros to every later attention sum, the paged==ring invariant), and
        re-derives ``ended_think`` over the kept tokens.  ``pmon`` (the
        proxy's MonitorState) replaces the generator's inert monitor so
        harvest/traces read the proxy's stop flags and EMA state.  A row
        with no overshoot passes through unchanged.  DONATES ``state``.
        """
        return self.retract_program(state)(
            state, jnp.asarray(new_n, jnp.int32), pmon
        )

    def retract_lagged_program(self, state: ServeState):
        key = ("retract", int(state.active.shape[0]),
               self._kind(state.cache), "lagged")
        if key not in self._programs:
            ecfg = self.ecfg

            def fn(state: ServeState, new_n, pmon: MonitorState) -> ServeState:
                stop = pmon.stop_flag
                # only proxy-STOPPED rows rewind: the others have already
                # decoded one more chunk whose tokens the proxy has not
                # observed yet — their counts must survive this dispatch
                eff = jnp.where(stop, new_n, state.n_reasoning)
                overshoot = state.n_reasoning - eff
                next_pos = state.next_pos - overshoot
                cache = dict(state.cache)
                cache["pos"] = jnp.where(
                    cache["pos"] >= next_pos[:, None], -1, cache["pos"]
                )
                cols = jnp.arange(state.out_tokens.shape[1],
                                  dtype=jnp.int32)[None]
                keep = cols < eff[:, None]
                last = jnp.take_along_axis(
                    state.out_tokens, (eff - 1)[:, None], 1)[:, 0]
                ended = (jnp.where(keep, state.out_tokens, -1)
                         == ecfg.end_think_id).any(-1)
                return ServeState(
                    cache=cache,
                    rng=state.rng,
                    active=state.active & ~stop,
                    next_pos=next_pos,
                    last_token=last,
                    n_reasoning=eff,
                    monitor=pmon,
                    ended_think=ended,
                    out_tokens=jnp.where(keep, state.out_tokens, ecfg.pad_id),
                    out_len=eff,
                )

            if self.ctx.mesh is None:
                jitted = jax.jit(fn, donate_argnums=0)
            else:
                ssh = self._state_sh(state)
                b = self._batch_entry(int(state.active.shape[0]))
                jitted = jax.jit(
                    fn,
                    in_shardings=(
                        ssh,
                        self._ns(P(b)),
                        jax.tree_util.tree_map(lambda _: self._ns(P(b)),
                                               state.monitor),
                    ),
                    out_shardings=ssh,
                    donate_argnums=0,
                )
            self._programs[key] = jitted
        return self._programs[key]

    def retract_lagged(self, state: ServeState, new_n, pmon: MonitorState
                       ) -> ServeState:
        """Overlap-mode reconciliation, applied one chunk boundary late:
        ``new_n``/``pmon`` are the proxy's verdict on chunk N while
        ``state`` has already decoded chunk N+1.  Rows the proxy stopped
        rewind exactly as ``retract`` does (their chunk-N overshoot AND
        their whole speculative chunk N+1 are position-masked away); every
        other row passes through untouched — its chunk-N+1 tokens are
        valid and still awaiting the proxy's next observation.  The proxy
        monitor replaces the generator's inert one wholesale, same as the
        sync retract.  DONATES ``state``."""
        return self.retract_lagged_program(state)(
            state, jnp.asarray(new_n, jnp.int32), pmon
        )

    def rollout_program(self, cache, B: int, n: int, greedy: bool):
        key = ("rollout", B, n, greedy, self._kind(cache))
        if key not in self._programs:
            model, cfg, ecfg = self.model, self.cfg, self.ecfg

            def positions(pos1d):
                return positions_for(cfg, pos1d)

            def fn(params, cache, next_pos, last_token, rng):
                et = jnp.full((B, 1), ecfg.end_think_id, jnp.int32)
                pos1d = next_pos[:, None]
                logits, cache2 = model.decode_step(
                    params, et, positions(pos1d), pos1d, cache
                )
                scfg = dataclasses.replace(ecfg.sampler, greedy=greedy)

                def step(carry, _):
                    cache_c, pos_c, logit_c, rng_c = carry
                    rng_c, sub = jax.random.split(rng_c)
                    tok = sample(sub, logit_c, cfg.vocab, scfg)
                    lp = logprob_of(logit_c, tok, cfg.vocab)
                    p1 = pos_c[:, None]
                    lg, cache_c = model.decode_step(
                        params, tok[:, None], positions(p1), p1, cache_c
                    )
                    return (cache_c, pos_c + 1, lg[:, -1], rng_c), (tok, lp)

                (_, _, _, _), (toks, lps) = jax.lax.scan(
                    step, (cache2, next_pos + 1, logits[:, -1], rng),
                    None, length=n,
                )
                return jnp.moveaxis(toks, 0, 1), jnp.moveaxis(lps, 0, 1)

            if self.ctx.mesh is None:
                jitted = jax.jit(fn)
            else:
                b = self._batch_entry(B)
                jitted = jax.jit(fn, in_shardings=(
                    self._param_sh,
                    self._sh(cache_pspecs(self.cfg, self.ctx, cache)),
                    self._ns(P(b)),
                    self._ns(P(b)),
                    self._ns(P()),
                ))
            self._programs[key] = jitted
        return self._programs[key]

    def rollout(self, params, cache, next_pos, last_token, rng, *, n: int,
                greedy: bool = False):
        """Forced answer rollout: append </think> then generate n tokens.
        Returns (tokens (B,n), logprobs (B,n)).  The cache is NOT donated:
        rollouts are functional reads of a live cache the caller keeps
        decoding from (``reason_with_trace``) or re-rolls K times
        (``rollout_answers``) — donation here would corrupt the sequence."""
        return self.rollout_program(cache, int(next_pos.shape[0]), n, greedy)(
            params, cache, next_pos, last_token, rng
        )


# --------------------------------------------------------------------------
# ProxyExecutor: the black-box monitor tier's program store
# --------------------------------------------------------------------------

class ProxyExecutor(Executor):
    """Program store for the proxy (black-box monitor) model.

    The proxy tier (paper §4.2, Fig. 5) is a SECOND model — own params, own
    KV cache (ring or paged, own page pool), own mesh context — that shadows
    the generator's emitted token chunks and computes EAT from *its* logits.
    Its decode state is a regular ``ServeState`` (the ``rng`` /
    ``last_token`` / ``out_tokens`` rows are inert bookkeeping), so every
    structural program is inherited from ``Executor`` unchanged: ``prefill``
    for prompts, ``admit`` / ``admit_paged`` for slot recycling in lock-step
    with the generator's admissions, ``pack_paged`` / ``put_page_table`` for
    the proxy's own page pool.  The one new program is ``observe_chunk`` —
    the forced-input shadow decode.  The generator executor, by contrast,
    never builds a probe or monitored-chunk program in proxy mode (the
    black-box contract; audited in tests/test_proxy_serve.py).
    """

    def __init__(self, model: Model, params, ecfg,
                 monitor: ReasoningMonitor):
        super().__init__(model, params, ecfg, monitor)
        self._shadow = make_shadow_step(model, monitor)

    def observe_chunk(self, params, pstate: ServeState, gen_tokens,
                      n_start, n_emitted, chunk_len) -> ServeState:
        """Shadow one generator chunk through the proxy model.

        ``gen_tokens`` (B, T) is the generator's ``out_tokens`` buffer after
        the chunk; ``n_start`` (B,) the per-row emitted count before it and
        ``n_emitted`` (B,) the tokens it added.  Step ``i`` re-feeds the
        token the generator consumed (``gen_tokens[b, n_start+i-1]``) into
        the proxy cache and due-checks the token it emitted
        (``gen_tokens[b, n_start+i]``), replaying the self-EAT monitor
        transition on the proxy's logits.  A row stops consuming the moment
        its stop latches (``monitor.stop_flag``) — the proxy cache never
        ingests overshoot tokens, so it stays aligned with the retracted
        generator stream.  ``pstate.n_reasoning`` tracks the corrected
        emitted count (the ``retract`` program's ``new_n``).  DONATES
        ``pstate``.
        """
        return self.observe_chunk_program(pstate, int(gen_tokens.shape[1]))(
            params, pstate, jnp.asarray(gen_tokens, jnp.int32),
            jnp.asarray(n_start, jnp.int32),
            jnp.asarray(n_emitted, jnp.int32),
            jnp.asarray(chunk_len, jnp.int32),
        )

    def observe_chunk_program(self, pstate: ServeState, T: int):
        B = int(pstate.active.shape[0])
        key = ("shadow", B, T, self._kind(pstate.cache))
        if key not in self._programs:
            shadow = self._shadow

            def fn(params, st: ServeState, toks, n_start, n_emitted,
                   chunk_len) -> ServeState:
                def valid_of(s, i):
                    return (i < n_emitted) & ~s.monitor.stop_flag

                def cond(carry):
                    i, s = carry
                    return (i < chunk_len) & valid_of(s, i).any()

                def body(carry):
                    i, s = carry
                    valid = valid_of(s, i)
                    tok_in = jnp.take_along_axis(
                        toks, (n_start + i - 1)[:, None], 1)
                    tok_out = jnp.take_along_axis(
                        toks, (n_start + i)[:, None], 1)[:, 0]
                    cache, mon, new_pos = shadow(
                        params, s.cache, tok_in, tok_out, s.next_pos,
                        s.monitor, valid,
                    )
                    inc = valid.astype(jnp.int32)
                    s = s._replace(
                        cache=cache,
                        monitor=mon,
                        next_pos=new_pos,
                        last_token=jnp.where(valid, tok_out, s.last_token),
                        n_reasoning=s.n_reasoning + inc,
                        out_len=s.out_len + inc,
                        active=valid & ~mon.stop_flag,
                    )
                    return i + 1, s

                _, st = jax.lax.while_loop(
                    cond, body, (jnp.zeros((), jnp.int32), st)
                )
                return st

            if self.ctx.mesh is None:
                jitted = jax.jit(fn, donate_argnums=1)
            else:
                ssh = self._state_sh(pstate)
                tok_sp, row_sp = proxy_stream_pspecs(self.ctx, B)
                jitted = jax.jit(
                    fn,
                    in_shardings=(self._param_sh, ssh, self._ns(tok_sp),
                                  self._ns(row_sp), self._ns(row_sp),
                                  self._ns(P())),
                    out_shardings=ssh,
                    donate_argnums=1,
                )
            self._programs[key] = jitted
        return self._programs[key]
