"""Overlapped (double-buffered) serve loop: the host layer between
scheduler and executor.

The synchronous loop in ``engine.serve`` pays one full host round trip per
chunk boundary: dispatch chunk N, block on its scalars, harvest, admit,
push page tables, dispatch chunk N+1.  As the mesh grows the device time
per chunk shrinks while the host time per boundary does not — the weak-
scaling cliff ``artifacts/BENCH_serve_scaling.json`` documents.  This
module restructures the loop around a one-deep software pipeline:

    tick t:   dispatch chunk F        (no host sync — the snapshot is a
                                       future, not a value)
              process boundary F-1    (np.asarray on chunk F-1's snapshot
                                       blocks only on F-1; F keeps running)

``Executor.decode_chunk_snapshot`` returns every host-facing scalar in
FRESH buffers (shapes distinct from all state fields, so XLA can never
alias them into the donated state), which is what lets chunk F be
dispatched before anything of F-1 has been read.  Harvests, admissions,
page-table pushes, and — in proxy mode — the shadow ``observe_chunk`` all
happen inside the overlap window; the proxy's ``retract`` reconciliation
lands one boundary late (``Executor.retract_lagged``), costing at most one
chunk of exit latency and zero tokens (token streams are bit-identical to
the sync loop under greedy sampling — ``tests/test_async_serve.py``).

Host-side consistency is the job of two pieces of pure-host bookkeeping:

* ``scheduler.InFlightLedger`` — dispatch fences.  A harvested row's KV
  pages stay OUT of the allocator free list until the fence open at
  harvest time retires (the in-flight chunk's page table still maps
  them); a slot re-admitted while chunk F is in flight is skipped in
  chunk F's snapshot (its row there belongs to the previous occupant).
* host **mirrors** of the ring pointer and per-row token counts, updated
  from each retired snapshot.  They lag the device by at most one
  dispatched chunk, so page mapping passes ``slack = chunk_len`` extra
  slots to over-cover the in-flight writes (see
  ``Executor.ensure_chunk_pages``) and the ring-capacity guard checks
  ``mirror_cur + chunk_len`` — admission under overlap therefore wants
  one chunk of extra capacity headroom (docs/serving.md).

Layering contract (enforced by ``tools/audit``): this module is DISPATCH
ONLY — it builds no jitted programs (executor-only-jit) and never calls
``jax.block_until_ready`` / ``device_get``; the single sanctioned blocking
read is ``np.asarray`` on a *snapshot* (never on donated state).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.executor import SNAP_ROWS
from repro.serving.scheduler import InFlightLedger, pools_can_admit

# positional indices into the snapshot's (len(SNAP_ROWS), B) int block
(SNAP_ACTIVE, SNAP_NR, SNAP_OUTLEN, SNAP_ENDED, SNAP_STOP, SNAP_EVALS,
 SNAP_CUR) = range(len(SNAP_ROWS))


class PipelineHooks:
    """Observation/interference seam for the overlapped loop.

    Every pipeline event calls the matching no-op method below; tests
    subclass to (a) record the event order — asserting, e.g., that chunk
    F+1's dispatch precedes boundary F's harvest — and (b) FORCE
    adversarial schedules: a hook that blocks on the snapshot inside
    ``on_dispatch`` degenerates the pipeline to harvest-before-dispatch,
    pinning that correctness never depends on the overlap actually
    overlapping.  Hooks run on the host thread; raising aborts the serve.
    """

    def on_dispatch(self, fence: int, snap: dict) -> None:
        """Chunk ``fence`` dispatched; ``snap`` is its (unread) snapshot."""

    def on_retire(self, fence: int) -> None:
        """Boundary ``fence`` read back; its deferred page frees released."""

    def on_observe(self, fence: int, pstate) -> None:
        """Proxy shadow of chunk ``fence`` observed (proxy mode only)."""

    def on_retract(self, fence: int) -> None:
        """Lagged retract for boundary ``fence`` dispatched (proxy mode)."""

    def on_harvest(self, fence: int, slots: list[int]) -> None:
        """Requests in ``slots`` finished at boundary ``fence``."""

    def on_admit(self, fence: int, slot: int) -> None:
        """A queued request admitted into ``slot`` while ``fence`` flies."""


def serve_overlapped(engine, ss, *, answer_len: int = 0,
                     record_trace: bool = False,
                     hooks: PipelineHooks | None = None) -> list[dict]:
    """The overlapped serve loop body.  ``ss`` is the namespace from
    ``ReasoningEngine._serve_setup`` (prefilled initial cohort, scheduler,
    allocators, proxy tier); results are identical in shape and — under
    greedy sampling — in content to the sync loop's."""
    ex = engine.executor
    ecfg = engine.ecfg
    sched, alloc, ptier = ss.sched, ss.alloc, ss.ptier
    paged, proxy_mode = ss.paged, ss.proxy_mode
    S, B, budget, chunk_py = ss.S, ss.B, ss.budget, ss.chunk_py
    state = ss.state
    rng = ss.rng
    hooks = hooks if hooks is not None else PipelineHooks()

    ledger = InFlightLedger()
    engine._ledger = ledger          # post-serve stats (tests/benches)
    for s, req in sched.bound():
        req.admitted_fence = ledger.mark_admitted(s)   # fence 0: never skipped

    # host mirrors from the last retired boundary (setup values to start);
    # lag the device by <= one dispatched chunk — all page/capacity math
    # below over-covers that lag with `slack`/`chunk_py` headroom
    mirror_nr = np.ones((B,), np.int32)
    mirror_outlen = np.ones((B,), np.int32)
    mirror_cur = ss.cur0

    def dispatch_tick():
        """Dispatch the next chunk without reading anything back."""
        nonlocal state
        bound = [(s, r) for s, r in sched.bound()]
        if paged:
            state = ex.ensure_chunk_pages(
                alloc, state, [s for s, _ in bound], chunk_py + ss.gen_tail,
                tail=ss.gen_tail, budget=budget, cur=mirror_cur,
                n_reasoning=mirror_nr,
                slack=chunk_py if ledger.in_flight else 0,
            )
        state, snap = ex.decode_chunk_snapshot(
            engine.params, state, ss.budget_dev, ss.chunk,
            use_monitor=ss.gen_monitor,
        )
        fence = ledger.open_fence()
        hooks.on_dispatch(fence, snap)
        return fence, snap, bound

    def process_boundary(fence, snap, bound):
        """Read boundary ``fence``'s snapshot (blocks only on that chunk),
        reconcile, harvest, and admit — all while the next chunk flies."""
        nonlocal state, rng, mirror_cur
        ints = np.asarray(snap["ints"])
        var_np = np.asarray(snap["var"])
        toks = np.asarray(snap["tokens"])[:, :-1]
        active_np = ints[SNAP_ACTIVE].astype(bool)
        nr = ints[SNAP_NR]
        outlen = ints[SNAP_OUTLEN]
        ended = ints[SNAP_ENDED].astype(bool)
        stop = ints[SNAP_STOP].astype(bool)
        evals = ints[SNAP_EVALS]
        cur = int(ints[SNAP_CUR, 0])
        ledger.retire_fence(fence)          # releases deferred page frees
        hooks.on_retire(fence)
        # slots re-admitted while this chunk flew: their snapshot rows are
        # the PREVIOUS occupant's — ignore them everywhere below
        skip = ledger.admitted_after(fence)

        new_n = pstop = pevals = pvar = None
        if proxy_mode:
            # shadow this boundary's emitted tokens through the proxy (on
            # its own dispatch chain — concurrent with the generator's
            # in-flight chunk), then reconcile the generator ONE boundary
            # late: only proxy-stopped rows rewind (retract_lagged)
            n_start = mirror_outlen.copy()
            n_emitted = (outlen - n_start).astype(np.int32)
            for s in skip:
                n_emitted[s] = 0
            ptier.begin_chunk(chunk_py, [s for s, _ in sched.bound()])
            new_n_dev, pmon = ptier.observe(toks, n_start, n_emitted,
                                            chunk_py)
            new_n = np.asarray(new_n_dev)
            pstop = np.asarray(pmon.stop_flag).astype(bool)
            pevals = np.asarray(pmon.n_evals)
            pvar = np.asarray(
                engine.monitor.stopper.debiased_var(pmon.stop_state))
            hooks.on_observe(fence, ptier.state)
            state = ex.retract_lagged(state, engine._across_tiers(new_n_dev),
                                      engine._across_tiers(pmon))
            hooks.on_retract(fence)

        if record_trace:
            # ``bound`` was captured at dispatch — exactly the rows that
            # decoded this chunk; already-finished requests self-guard
            for s, req in bound:
                if proxy_mode:
                    req.record_trace(new_n[s], pevals[s], pvar[s])
                else:
                    req.record_trace(nr[s], evals[s], var_np[s])

        if proxy_mode:
            active_eff = active_np & ~pstop
        else:
            active_eff = active_np
        done = [(s, r) for s, r in sched.finished_slots(active_eff)
                if s not in skip]

        ans = None
        if answer_len and done:
            if paged:
                # rollout writes </think> + answer_len slots past cur; the
                # in-flight chunk may already have advanced the ring, so
                # over-map by one chunk of slack
                state = ex.ensure_chunk_pages(
                    alloc, state, [s for s, _ in sched.bound()],
                    answer_len + 1, cur=cur,
                    slack=chunk_py if ledger.in_flight else 0,
                )
            toks_ans, _ = engine.force_answer(state, answer_len, greedy=True)
            ans = np.asarray(toks_ans)

        for s, req in done:
            sched.release(s)
            ledger.mark_released(s, fence)
            if proxy_mode:
                n_fin = int(new_n[s]) if pstop[s] else int(nr[s])
                eat_s = bool(pstop[s])
                # recompute off the truncated stream — the snapshot's flag
                # may predate the lagged rewind
                ended_s = bool((toks[s, :n_fin] == ecfg.end_think_id).any())
            else:
                n_fin = int(nr[s])
                eat_s = bool(stop[s])
                ended_s = bool(ended[s])
            req.finish(
                reasoning_tokens=toks[s, :n_fin].copy(),
                n_reasoning=n_fin,
                ended_think=ended_s,
                eat_stop=eat_s,
                answer_tokens=ans[s].copy() if ans is not None else None,
            )
            if paged:
                # the in-flight chunk's page table still maps this row's
                # pages: park them on the ledger until its fence retires
                ledger.defer_free(alloc, s)
            if ptier is not None:
                # the proxy chain was synced by the observe read above —
                # its pages can go straight back to the pool
                ptier.free_row(s)
        if done:
            hooks.on_harvest(fence, [s for s, _ in done])

        # mirrors advance to this boundary's (post-verdict) values; skip
        # rows keep their admission-time values — their snapshot data here
        # belongs to the previous occupant
        for s in range(B):
            if s in skip:
                continue
            if proxy_mode and pstop[s]:
                mirror_nr[s] = mirror_outlen[s] = new_n[s]
            else:
                mirror_nr[s] = nr[s]
                mirror_outlen[s] = outlen[s]
        mirror_cur = cur

        # admission sweeps EVERY free slot (deferred admissions included);
        # the ring guard uses the mirror plus one in-flight chunk of
        # headroom — an upper bound on the true pointer
        for s in (s for s, r in enumerate(sched.slots) if r is None):
            if sched.pending == 0:
                continue
            used_ub = mirror_cur + (chunk_py if ledger.in_flight else 0)
            sched.check_capacity(used_ub, "another admission")
            if ptier is not None:
                ptier.check_capacity("another admission")
            if not pools_can_admit(S, alloc,
                                   ptier.alloc if ptier else None):
                for a in (alloc, ptier.alloc if ptier else None):
                    if a is not None and not a.can_admit(S):
                        a.deferrals += 1
                continue
            nxt = sched.admit_next(s)
            rng, sub = jax.random.split(rng)
            one = engine.start(jnp.asarray(nxt.prompt[None]),
                               jnp.asarray([nxt.prompt_len]), sub,
                               capacity=ss.C_pre)
            if paged:
                row_table = alloc.admit_row(s, S, used_ub)
                state = ex.admit_paged(state, one, s, row_table)
            else:
                state = engine._admit(state, one, s)
            if ptier is not None:
                ptier.admit(s, nxt.prompt, nxt.prompt_len, S)
            nxt.begin_decode()
            nxt.admitted_fence = ledger.mark_admitted(s)
            mirror_nr[s] = mirror_outlen[s] = 1
            hooks.on_admit(ledger.fence, s)

    # ---- the pipeline: always dispatch-ahead, then read the PREVIOUS
    # boundary.  Chunks whose rows all turned inactive execute zero device
    # steps (the while_loop cond short-circuits), so the unconditional
    # dispatch never needs a host sync to decide — at most one trailing
    # no-op chunk per drain versus the sync loop.
    pend = None
    while True:
        while sched.running:
            nxt_pend = dispatch_tick()
            if pend is not None:
                process_boundary(*pend)
            pend = nxt_pend
        if pend is not None:
            process_boundary(*pend)   # retires the last fence; may admit
            pend = None
            continue
        if sched.pending == 0:
            break
        # every slot empty, queue non-empty, all fences retired and every
        # deferred free released: a pool genuinely too small — same
        # fail-fast sizing hints as the sync loop
        if paged and not alloc.can_admit(S):
            raise RuntimeError(
                f"paged KV cache cannot hold a single request: "
                f"{alloc.free_pages} pages free with every slot "
                f"empty, but a prompt needs "
                f"{alloc.blocks_for(S) + 1} pages. "
                f"Raise CacheConfig.num_pages."
            )
        if ptier is not None and not ptier.can_admit(S):
            raise RuntimeError(
                f"proxy paged KV cache cannot hold a single "
                f"request: {ptier.alloc.free_pages} pages free with "
                f"every slot empty, but a prompt needs "
                f"{ptier.alloc.blocks_for(S) + 1} pages. "
                f"Raise ProxyConfig.cache.num_pages."
            )
        break

    return [r.to_result() for r in ss.requests]
