"""Single choke-point for jax API churn.

Everything here exists because the public jax surface moved between 0.4.x
and 0.5+/0.6+; routing all call sites through one module makes the next
jax bump a one-file change:

* ``shard_map``       — lived in ``jax.experimental.shard_map`` through
  0.4.x, was promoted to ``jax.shard_map`` later; the replication-check
  kwarg was also renamed ``check_rep`` -> ``check_vma``.
* ``make_abstract_mesh`` — ``AbstractMesh``'s calling convention changed
  from ``AbstractMesh(((name, size), ...))`` pairs (0.4.x) to
  ``AbstractMesh(axis_sizes, axis_names)``.
* ``cost_analysis_dict`` — ``Compiled.cost_analysis()`` returns a list of
  per-computation dicts on 0.4.x and a plain dict on newer releases.
"""
from __future__ import annotations

import jax

try:  # jax >= 0.5: public top-level export
    from jax import shard_map as _shard_map  # type: ignore[attr-defined]
    _VMA_KWARG = "check_vma"
except ImportError:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map
    _VMA_KWARG = "check_rep"


def shard_map(f, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` with the replication-check kwarg normalized.

    ``check_vma`` follows the new-jax name; on 0.4.x it is forwarded as
    ``check_rep`` (same semantics: verify out_specs replication claims).
    """
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **{_VMA_KWARG: check_vma})


def make_abstract_mesh(shape: tuple[int, ...], axis_names: tuple[str, ...]):
    """Device-free mesh for pure sharding-spec logic (no real devices)."""
    from jax.sharding import AbstractMesh

    try:  # jax >= 0.5-ish: AbstractMesh(axis_sizes, axis_names)
        return AbstractMesh(shape, axis_names)
    except TypeError:  # jax 0.4.x: AbstractMesh(((name, size), ...))
        return AbstractMesh(tuple(zip(axis_names, shape)))


def cost_analysis_dict(compiled) -> dict:
    """Normalized ``Compiled.cost_analysis()``: always one flat dict.

    jax 0.4.x returns ``[{...}]`` (one dict per computation, usually a
    singleton); newer jax returns the dict directly.  Multi-entry lists are
    summed key-wise — callers read aggregate flops / bytes accessed.
    """
    cost = compiled.cost_analysis()
    if cost is None:
        return {}
    if isinstance(cost, dict):
        return cost
    out: dict = {}
    for entry in cost:
        for k, v in entry.items():
            if isinstance(v, (int, float)):
                out[k] = out.get(k, 0.0) + v
            else:
                out.setdefault(k, v)
    return out
