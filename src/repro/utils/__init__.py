from repro.utils.treeutil import param_bytes, param_count, tree_flatten_with_paths  # noqa: F401
