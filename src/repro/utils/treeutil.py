"""Small pytree utilities."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def tree_flatten_with_paths(tree):
    """Yield (path_string, leaf) pairs, e.g. 'layers/attn/wq'."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        keys = []
        for p in path:
            if isinstance(p, jax.tree_util.DictKey):
                keys.append(str(p.key))
            elif isinstance(p, jax.tree_util.SequenceKey):
                keys.append(str(p.idx))
            elif isinstance(p, jax.tree_util.GetAttrKey):
                keys.append(p.name)
            else:
                keys.append(str(p))
        out.append(("/".join(keys), leaf))
    return out


def param_count(tree) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(tree))


def param_bytes(tree) -> int:
    return sum(
        int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize
        for x in jax.tree_util.tree_leaves(tree)
    )
