"""SeamlessM4T-large v2 — encoder-decoder, audio (text decoder backbone).
[arXiv:2308.11596]

24L (each side) d_model=1024, 16 heads, d_ff=8192, vocab=256206.  The speech
frontend (mel + conformer conv) is a STUB: ``input_specs`` provides
precomputed 1024-dim frame embeddings (encoder_len frames).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="seamless-m4t-large-v2",
        arch_type="encdec",
        source="arXiv:2308.11596",
        n_layers=24,            # decoder layers
        n_encoder_layers=24,
        encoder_len=1024,       # stub frontend frames
        d_model=1024,
        n_heads=16,
        n_kv_heads=16,
        d_ff=8192,
        vocab=256_206,
        activation="gelu",
    )
)
