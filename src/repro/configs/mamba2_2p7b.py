"""Mamba2-2.7B — attention-free SSD state-space model. [arXiv:2405.21060]

64L d_model=2560, d_state=128, expand=2 (d_inner=5120), head_dim=64
(80 SSD heads), vocab 50280.
"""
from repro.configs.base import ModelConfig, SSMConfig, register

CONFIG = register(
    ModelConfig(
        name="mamba2-2.7b",
        arch_type="ssm",
        source="arXiv:2405.21060",
        n_layers=64,
        d_model=2560,
        n_heads=1,      # unused by SSM blocks
        n_kv_heads=1,
        d_ff=0,
        vocab=50_280,
        ssm=SSMConfig(d_state=128, head_dim=64, expand=2, chunk=128, conv_width=4, n_groups=1),
    )
)
