"""DeepSeek-V2 236B (21B active) — MLA + fine-grained MoE. [arXiv:2405.04434]

60L d_model=5120, 128 heads, MLA kv_lora=512 (q_lora=1536, nope=128, rope=64,
v=128), MoE: 2 shared + 160 routed experts, top-6, d_expert=1536, layer 0
dense FFN (d_ff=12288), vocab 102400.
"""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig, register

CONFIG = register(
    ModelConfig(
        name="deepseek-v2-236b",
        arch_type="moe",
        source="arXiv:2405.04434",
        n_layers=60,
        d_model=5120,
        n_heads=128,
        n_kv_heads=128,
        head_dim=128,
        d_ff=12288,  # dense layer d_ff (layer 0)
        vocab=102_400,
        activation="silu",
        rope_theta=10_000.0,
        moe=MoEConfig(
            n_routed=160,
            n_shared=2,
            top_k=6,
            d_expert=1536,
            first_k_dense=1,
            dense_d_ff=12288,
            router_aux_weight=0.003,
        ),
        mla=MLAConfig(
            kv_lora_rank=512,
            q_lora_rank=1536,
            qk_nope_head_dim=128,
            qk_rope_head_dim=64,
            v_head_dim=128,
        ),
    )
)
