"""Model / run configuration system.

Every architecture is described by a single frozen ``ModelConfig`` dataclass.
Configs are registered by id in ``REGISTRY`` (one module per assigned
architecture under ``repro/configs``) and selected with ``--arch <id>`` by the
launchers.  ``reduced()`` derives the CPU-smoke-test variant of the same
family (≤2 layers, d_model ≤ 512, ≤4 experts) mandated for per-arch smoke
tests.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field, replace
from typing import Literal, Sequence

ArchType = Literal["dense", "moe", "ssm", "hybrid", "encdec", "vlm"]
Activation = Literal["silu", "geglu", "gelu"]


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts sub-config (DeepSeek-style fine-grained MoE)."""

    n_routed: int = 0                 # number of routed experts
    n_shared: int = 0                 # always-on shared experts
    top_k: int = 0                    # experts per token
    d_expert: int = 0                 # hidden dim of each expert FFN
    first_k_dense: int = 1            # leading layers that use a dense FFN
    dense_d_ff: int = 0               # d_ff of those dense layers
    capacity_factor: float = 1.25     # expert-parallel capacity factor
    router_aux_weight: float = 0.001  # load-balance aux loss weight
    routed_scale: float = 1.0         # scaling on routed output (DeepSeek uses 1.0)


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) sub-config."""

    d_state: int = 128
    head_dim: int = 64                # P in SSD
    expand: int = 2                   # d_inner = expand * d_model
    chunk: int = 128                  # SSD chunk length
    conv_width: int = 4
    n_groups: int = 1                 # B/C groups (like GQA for SSM)


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 multi-head latent attention sub-config."""

    kv_lora_rank: int = 512
    q_lora_rank: int = 1536
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class ModelConfig:
    name: str = "tiny"
    arch_type: ArchType = "dense"
    source: str = ""                  # citation: arXiv id / model card

    n_layers: int = 2
    d_model: int = 128
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 0                 # 0 -> d_model // n_heads
    d_ff: int = 512
    vocab: int = 256

    activation: Activation = "silu"
    qk_norm: bool = False
    attn_bias: bool = False           # qwen1.5-style qkv bias
    tie_embeddings: bool = False
    embed_scale: bool = False         # gemma: scale embeddings by sqrt(d)
    rmsnorm_one_plus: bool = False    # gemma: (1 + w) * normed
    norm_eps: float = 1e-6
    rope_theta: float = 10_000.0
    mrope_sections: tuple[int, ...] = ()   # qwen2-vl M-RoPE (t, h, w)
    logit_softcap: float = 0.0

    # attention variants
    sliding_window: int = 0           # 0 = full attention; >0 = SWA window
    attn_temperature: float = 0.0     # 0 -> 1/sqrt(head_dim)

    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    mla: MLAConfig | None = None

    # hybrid: pattern of block kinds, tiled to n_layers. e.g. Zamba2:
    # ("ssm",)*5 + ("shared_attn",) repeated.  "shared_attn" blocks share one
    # parameter set across all their occurrences.
    hybrid_pattern: tuple[str, ...] = ()

    # encoder-decoder (audio): encoder layer count; encoder consumes stub
    # frame embeddings of dim d_model.
    n_encoder_layers: int = 0
    encoder_len: int = 1024           # stub frontend frames per example

    # vlm: number of stub image-patch embeddings prepended to the stream
    n_image_patches: int = 0

    dtype: str = "bfloat16"
    # --------------------------------------------------------------

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Embedding-table vocab padded to a multiple of 256 so the vocab dim
        shards over a 16-wide model axis and tiles to the 128 TPU lane width
        (e.g. mamba2's 50280 -> 50432).  Logits beyond ``vocab`` are masked
        in loss / sampling / entropy."""
        return -(-self.vocab // 256) * 256

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // self.n_kv_heads

    @property
    def is_causal_lm(self) -> bool:
        return self.arch_type in ("dense", "moe", "ssm", "hybrid", "vlm")

    def block_kinds(self) -> tuple[str, ...]:
        """Per-layer block kind sequence."""
        if self.arch_type == "ssm":
            return ("ssm",) * self.n_layers
        if self.arch_type == "hybrid":
            pat = self.hybrid_pattern or ("ssm", "ssm", "ssm", "ssm", "ssm", "shared_attn")
            reps = math.ceil(self.n_layers / len(pat))
            return (pat * reps)[: self.n_layers]
        return ("attn",) * self.n_layers

    def moe_layer_mask(self) -> tuple[bool, ...]:
        """True where the FFN is MoE (False = dense FFN)."""
        if self.moe is None or self.moe.n_routed == 0:
            return (False,) * self.n_layers
        return tuple(i >= self.moe.first_k_dense for i in range(self.n_layers))

    # ---- parameter count (for roofline MODEL_FLOPS) -----------------
    def param_count(self, active_only: bool = False) -> int:
        d, hd = self.d_model, self.resolved_head_dim
        n_q, n_kv = self.n_heads, self.n_kv_heads
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        per_attn = d * (n_q * hd) + 2 * d * (n_kv * hd) + (n_q * hd) * d
        if self.mla is not None:
            m = self.mla
            qk_hd = m.qk_nope_head_dim + m.qk_rope_head_dim
            per_attn = (
                d * m.q_lora_rank + m.q_lora_rank * n_q * qk_hd          # q down/up
                + d * (m.kv_lora_rank + m.qk_rope_head_dim)              # kv down (+rope k)
                + m.kv_lora_rank * n_q * (m.qk_nope_head_dim + m.v_head_dim)
                + n_q * m.v_head_dim * d                                  # o proj
            )
        ffn_mult = 3 if self.activation in ("silu", "geglu") else 2
        per_dense_ffn = ffn_mult * d * self.d_ff

        def moe_ffn(active: bool) -> int:
            mo = self.moe
            n_e = (mo.top_k if active else mo.n_routed) + mo.n_shared
            return ffn_mult * d * mo.d_expert * n_e + d * mo.n_routed  # + router

        def ssm_params() -> int:
            s = self.ssm
            d_in = s.expand * d
            nh = d_in // s.head_dim
            bc = 2 * s.n_groups * s.d_state
            return d * (2 * d_in + bc + nh) + (d_in + bc) * s.conv_width + d_in * d + 2 * nh

        total = emb
        kinds = self.block_kinds()
        moe_mask = self.moe_layer_mask()
        shared_attn_counted = False
        for i, kind in enumerate(kinds):
            if kind == "ssm":
                total += ssm_params() + d  # + norm
            elif kind == "shared_attn":
                if not shared_attn_counted:
                    # Zamba2 shared block consumes concat(h, emb0): 2d input
                    total += 2 * d * (n_q * hd) * 1 + 2 * 2 * d * (n_kv * hd) + (n_q * hd) * d
                    total += ffn_mult * d * self.d_ff + 2 * d
                    shared_attn_counted = True
            else:
                total += per_attn + 2 * d
                if self.moe is not None and moe_mask[i]:
                    if self.moe.dense_d_ff and i < self.moe.first_k_dense:
                        total += ffn_mult * d * self.moe.dense_d_ff
                    else:
                        total += moe_ffn(active_only)
                elif self.moe is not None and not moe_mask[i]:
                    dff = self.moe.dense_d_ff or self.d_ff
                    total += ffn_mult * d * dff
                else:
                    total += per_dense_ffn
        # encoder (audio)
        if self.n_encoder_layers:
            total += self.n_encoder_layers * (per_attn + per_dense_ffn + 2 * d)
            # decoder cross attention
            total += self.n_layers * (per_attn + d)
        return total

    # ---- reduced variant for CPU smoke tests -------------------------
    def reduced(self) -> "ModelConfig":
        kw: dict = dict(
            name=self.name + "-reduced",
            n_layers=2,
            d_model=min(self.d_model, 128),
            vocab=min(self.vocab, 512),
        )
        hd = 32
        n_heads = max(2, min(self.n_heads, 4))
        n_kv = max(1, min(self.n_kv_heads, n_heads))
        kw.update(n_heads=n_heads, n_kv_heads=n_kv, head_dim=hd, d_ff=min(self.d_ff, 256) or 256)
        if self.moe is not None:
            kw["moe"] = replace(
                self.moe,
                n_routed=min(self.moe.n_routed, 4),
                n_shared=min(self.moe.n_shared, 1),
                top_k=min(self.moe.top_k, 2),
                d_expert=64,
                first_k_dense=min(self.moe.first_k_dense, 1),
                dense_d_ff=128 if self.moe.dense_d_ff else 0,
            )
        if self.ssm is not None:
            kw["ssm"] = replace(self.ssm, d_state=16, head_dim=16, chunk=16)
        if self.mla is not None:
            kw["mla"] = MLAConfig(
                kv_lora_rank=32, q_lora_rank=48, qk_nope_head_dim=32,
                qk_rope_head_dim=16, v_head_dim=32,
            )
        if self.hybrid_pattern:
            kw["n_layers"] = max(2, len(self.hybrid_pattern))
        if self.n_encoder_layers:
            kw["n_encoder_layers"] = 2
            kw["encoder_len"] = 32
        if self.n_image_patches:
            kw["n_image_patches"] = 8
        if self.mrope_sections:
            kw["mrope_sections"] = (4, 6, 6)  # sums to head_dim // 2 = 16
        kw["dtype"] = "float32"
        return replace(self, **kw)


# ----------------------------------------------------------------------
# registry
REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    if cfg.name in REGISTRY:
        raise ValueError(f"duplicate config {cfg.name}")
    REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    # import side-effect registration
    from repro import configs as _  # noqa: F401

    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(REGISTRY)}")
    return REGISTRY[name]


def list_configs() -> list[str]:
    from repro import configs as _  # noqa: F401

    return sorted(REGISTRY)


# ----------------------------------------------------------------------
# input shapes (assigned)
@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


INPUT_SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}
