"""Qwen2-VL-7B — VLM language backbone with M-RoPE. [arXiv:2409.12191]

28L d_model=3584, 28 heads (kv=4), d_ff=18944, vocab=152064, M-RoPE
sections (t,h,w)=(16,24,24) over head_dim=128.  The ViT vision encoder +
projector is a STUB: ``input_specs`` provides projected patch embeddings
(n_image_patches x d_model) prepended to the token stream.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="qwen2-vl-7b",
        arch_type="vlm",
        source="arXiv:2409.12191",
        n_layers=28,
        d_model=3584,
        n_heads=28,
        n_kv_heads=4,
        d_ff=18944,
        vocab=152_064,
        attn_bias=True,
        mrope_sections=(16, 24, 24),
        n_image_patches=256,
        rope_theta=1_000_000.0,
    )
)
