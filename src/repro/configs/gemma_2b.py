"""Gemma-2B — dense, GeGLU, head_dim=256, MQA (kv=1). [arXiv:2403.08295]

18L d_model=2048, 8 heads (kv=1), d_ff=16384, vocab=256000, tied embeddings,
embedding scaling by sqrt(d), (1+w) RMSNorm.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="gemma-2b",
        arch_type="dense",
        source="arXiv:2403.08295",
        n_layers=18,
        d_model=2048,
        n_heads=8,
        n_kv_heads=1,
        head_dim=256,
        d_ff=16384,
        vocab=256_000,
        activation="geglu",
        tie_embeddings=True,
        embed_scale=True,
        rmsnorm_one_plus=True,
    )
)
