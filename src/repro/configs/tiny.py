"""Tiny configs for CPU tests and the trained synthetic-reasoning example."""
from repro.configs.base import ModelConfig, MoEConfig, SSMConfig, register

TINY = register(
    ModelConfig(
        name="tiny",
        arch_type="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab=64,
        qk_norm=True,
        dtype="float32",
    )
)

# the black-box monitor model for the proxy-EAT serving tier (paper Fig. 5
# at toy scale: a much smaller same-tokenizer model whose probe FLOPs are a
# fraction of the generator's — benchmarks/engine_throughput.py --monitor
# proxy reports the ratio)
TINY_PROXY = register(
    ModelConfig(
        name="tiny-proxy",
        arch_type="dense",
        n_layers=1,
        d_model=32,
        n_heads=2,
        n_kv_heads=1,
        head_dim=16,
        d_ff=64,
        vocab=64,                # must match the generator's tokenizer
        qk_norm=True,
        dtype="float32",
    )
)

# the trained synthetic reasoning model used by examples/train_reasoner.py
TINY_REASONER = register(
    ModelConfig(
        name="tiny-reasoner",
        arch_type="dense",
        n_layers=3,
        d_model=96,
        n_heads=6,
        n_kv_heads=2,
        head_dim=16,
        d_ff=256,
        vocab=64,
        tie_embeddings=True,
        dtype="float32",
    )
)

TINY_MOE = register(
    ModelConfig(
        name="tiny-moe",
        arch_type="moe",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab=64,
        moe=MoEConfig(n_routed=4, n_shared=1, top_k=2, d_expert=32, first_k_dense=1, dense_d_ff=128),
        dtype="float32",
    )
)

TINY_SSM = register(
    ModelConfig(
        name="tiny-ssm",
        arch_type="ssm",
        n_layers=2,
        d_model=64,
        n_heads=1,
        n_kv_heads=1,
        d_ff=0,
        vocab=64,
        ssm=SSMConfig(d_state=16, head_dim=16, expand=2, chunk=16),
        dtype="float32",
    )
)
