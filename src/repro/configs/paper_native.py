"""Configs native to the EAT paper's own experiments.

``eat-paper-8b``: DeepSeek-R1-0528-Qwen3-8B-shaped reasoning model — the
paper's main reasoning model (Fig. 1-4).  ``eat-proxy-1.5b``:
DeepSeek-R1-Distill-Qwen-1.5B-shaped proxy for the black-box setting
(Fig. 3, bottom-left).
"""
from repro.configs.base import ModelConfig, register

PAPER_8B = register(
    ModelConfig(
        name="eat-paper-8b",
        arch_type="dense",
        source="hf:deepseek-ai/DeepSeek-R1-0528-Qwen3-8B",
        n_layers=36,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=12288,
        vocab=151_936,
        qk_norm=True,
        rope_theta=1_000_000.0,
    )
)

PAPER_PROXY_1P5B = register(
    ModelConfig(
        name="eat-proxy-1.5b",
        arch_type="dense",
        source="hf:deepseek-ai/DeepSeek-R1-Distill-Qwen-1.5B",
        n_layers=28,
        d_model=1536,
        n_heads=12,
        n_kv_heads=2,
        head_dim=128,
        d_ff=8960,
        vocab=151_936,
        attn_bias=True,
    )
)
