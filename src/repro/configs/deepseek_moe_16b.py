"""DeepSeekMoE-16B — fine-grained MoE: 2 shared + 64 routed, top-6.
[arXiv:2401.06066]

28L d_model=2048, 16 heads (kv=16), d_expert=1408, layer 0 dense
(d_ff=10944), vocab 102400.
"""
from repro.configs.base import ModelConfig, MoEConfig, register

CONFIG = register(
    ModelConfig(
        name="deepseek-moe-16b",
        arch_type="moe",
        source="arXiv:2401.06066",
        n_layers=28,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_ff=10944,
        vocab=102_400,
        moe=MoEConfig(
            n_routed=64,
            n_shared=2,
            top_k=6,
            d_expert=1408,
            first_k_dense=1,
            dense_d_ff=10944,
            router_aux_weight=0.001,
        ),
    )
)
