"""Gemma-7B — dense, GeGLU, head_dim=256. [arXiv:2403.08295]

28L d_model=3072, 16 heads (kv=16), d_ff=24576, vocab=256000, tied
embeddings, embedding scaling, (1+w) RMSNorm.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="gemma-7b",
        arch_type="dense",
        source="arXiv:2403.08295",
        n_layers=28,
        d_model=3072,
        n_heads=16,
        n_kv_heads=16,
        head_dim=256,
        d_ff=24576,
        vocab=256_000,
        activation="geglu",
        tie_embeddings=True,
        embed_scale=True,
        rmsnorm_one_plus=True,
    )
)
