"""Zamba2-2.7B — hybrid: Mamba2 backbone + shared attention block.
[arXiv:2411.15242]

54 Mamba2 layers d_model=2560 (ssm_state=64) with one SHARED
attention+MLP block (32 heads, d_ff=10240) applied every 6th position
(9 applications). The shared block consumes concat(hidden, embed0) (2*d)
per the paper; per-depth LoRA deltas are omitted (see DESIGN.md §5).
"""
from repro.configs.base import ModelConfig, SSMConfig, register

CONFIG = register(
    ModelConfig(
        name="zamba2-2.7b",
        arch_type="hybrid",
        source="arXiv:2411.15242",
        n_layers=54,
        d_model=2560,
        n_heads=32,
        n_kv_heads=32,
        head_dim=80,
        d_ff=10240,
        vocab=32_000,
        ssm=SSMConfig(d_state=64, head_dim=64, expand=2, chunk=128, conv_width=4),
        hybrid_pattern=("ssm", "ssm", "ssm", "ssm", "ssm", "shared_attn"),
    )
)
