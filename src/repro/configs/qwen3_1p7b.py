"""Qwen3-1.7B — dense, GQA (kv=8), qk_norm. [hf:Qwen/Qwen3-8B family card]

28L d_model=2048, 16 heads (kv=8), head_dim=128, d_ff=6144, vocab=151936,
tied embeddings.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="qwen3-1.7b",
        arch_type="dense",
        source="hf:Qwen/Qwen3-1.7B (family card hf:Qwen/Qwen3-8B)",
        n_layers=28,
        d_model=2048,
        n_heads=16,
        n_kv_heads=8,
        head_dim=128,
        d_ff=6144,
        vocab=151_936,
        qk_norm=True,
        tie_embeddings=True,
        rope_theta=1_000_000.0,
    )
)
