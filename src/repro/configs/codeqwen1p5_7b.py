"""CodeQwen1.5-7B — dense, qwen1.5 arch (qkv bias). [hf:Qwen/CodeQwen1.5-7B]

32L d_model=4096, 32 heads (MHA: kv=32), d_ff=13440, vocab=92416.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="codeqwen1.5-7b",
        arch_type="dense",
        source="hf:Qwen/CodeQwen1.5-7B",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=32,
        d_ff=13440,
        vocab=92_416,
        attn_bias=True,
        rope_theta=1_000_000.0,
    )
)
