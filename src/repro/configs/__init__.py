"""Config registry — importing this package registers all architectures."""
from repro.configs import (  # noqa: F401
    codeqwen1p5_7b,
    deepseek_moe_16b,
    deepseek_v2_236b,
    gemma_2b,
    gemma_7b,
    mamba2_2p7b,
    paper_native,
    qwen2_vl_7b,
    qwen3_1p7b,
    seamless_m4t_large_v2,
    tiny,
    zamba2_2p7b,
)
from repro.configs.base import (  # noqa: F401
    INPUT_SHAPES,
    REGISTRY,
    InputShape,
    MLAConfig,
    ModelConfig,
    MoEConfig,
    SSMConfig,
    get_config,
    list_configs,
)

ASSIGNED_ARCHS = [
    "deepseek-v2-236b",
    "mamba2-2.7b",
    "codeqwen1.5-7b",
    "seamless-m4t-large-v2",
    "gemma-2b",
    "deepseek-moe-16b",
    "zamba2-2.7b",
    "qwen3-1.7b",
    "qwen2-vl-7b",
    "gemma-7b",
]
