from repro.models.model import Model, cross_entropy_loss  # noqa: F401
