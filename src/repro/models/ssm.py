"""Mamba2 (SSD — state-space duality) block. [arXiv:2405.21060]

Training / prefill uses the chunked SSD algorithm:
  * intra-chunk: quadratic "attention-like" term with decay masking,
  * inter-chunk: associative scan over per-chunk (decay, state) pairs,
so the sequential dependence is only over S/chunk steps (log-depth via
``lax.associative_scan``), and the inner loops are MXU matmuls.

Decode carries a recurrent state pytree:
  ``ssm``  : (B, nh, N, hp)  per-head state  h_t = a_t h_{t-1} + dt_t B_t x_t
  ``conv`` : (B, w-1, conv_dim)  causal-conv ring tail.

Probing (EAT) uses ``ssm_step`` with ``commit=False`` semantics simply by
discarding the returned state — the SSM analogue of not committing the KV
cache (DESIGN.md §3).

The invalid-position convention matches attention: callers pass a ``valid``
mask; invalid steps get dt=0, x=0 => decay a=exp(0)=1 and zero input, i.e.
the state passes through unchanged.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models.common import dense_init, rmsnorm


class SSMDims(NamedTuple):
    d_inner: int
    n_heads: int
    head_dim: int
    n_groups: int
    d_state: int
    conv_dim: int
    conv_width: int
    chunk: int


def ssm_dims(cfg: ModelConfig) -> SSMDims:
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    nh = d_inner // s.head_dim
    conv_dim = d_inner + 2 * s.n_groups * s.d_state
    return SSMDims(d_inner, nh, s.head_dim, s.n_groups, s.d_state, conv_dim, s.conv_width, s.chunk)


def ssm_init(key, cfg: ModelConfig, dtype) -> dict:
    """Projections are stored *separately* (w_z/w_x/w_b/w_c/w_dt instead of a
    fused in_proj) so the tensor-parallel dims (d_inner, ssd heads) shard
    cleanly over the model axis while B/C (n_groups * d_state, tiny) stay
    replicated — see sharding/partition.py."""
    dm = ssm_dims(cfg)
    gn = dm.n_groups * dm.d_state
    ks = jax.random.split(key, 8)
    u = jax.random.uniform(ks[0], (dm.n_heads,), minval=math.log(1e-3), maxval=math.log(1e-1))
    dt_bias = jnp.log(jnp.expm1(jnp.exp(u)))  # inverse softplus
    return {
        "w_z": dense_init(ks[1], cfg.d_model, dm.d_inner, dtype),
        "w_x": dense_init(ks[2], cfg.d_model, dm.d_inner, dtype),
        "w_b": dense_init(ks[3], cfg.d_model, gn, dtype),
        "w_c": dense_init(ks[4], cfg.d_model, gn, dtype),
        "w_dt": dense_init(ks[5], cfg.d_model, dm.n_heads, dtype),
        "conv_x_w": (jax.random.normal(ks[6], (dm.conv_width, dm.d_inner)) * 0.1).astype(dtype),
        "conv_x_b": jnp.zeros((dm.d_inner,), dtype),
        "conv_bc_w": (jax.random.normal(ks[7], (dm.conv_width, 2 * gn)) * 0.1).astype(dtype),
        "conv_bc_b": jnp.zeros((2 * gn,), dtype),
        "dt_bias": dt_bias.astype(jnp.float32),
        "A_log": jnp.log(jnp.arange(1, dm.n_heads + 1, dtype=jnp.float32)),
        "D": jnp.ones((dm.n_heads,), jnp.float32),
        "norm_w": jnp.ones((dm.d_inner,), dtype),
        "out_proj": dense_init(ks[0], dm.d_inner, cfg.d_model, dtype),
    }


def _proj(p: dict, x: jax.Array, dm: SSMDims):
    """x -> (z, x_conv_in, bc_conv_in, dt_raw)."""
    z = x @ p["w_z"]
    xi = x @ p["w_x"]
    bc = jnp.concatenate([x @ p["w_b"], x @ p["w_c"]], axis=-1)
    dt_raw = x @ p["w_dt"]
    return z, xi, bc, dt_raw


def _causal_conv(xs: jax.Array, w: jax.Array, b: jax.Array, tail: jax.Array | None):
    """Depthwise causal conv.  xs: (B,S,C); w: (W,C); tail: (B,W-1,C) or None.

    Returns (silu(y), new_tail).
    """
    W = w.shape[0]
    Bsz, S, C = xs.shape
    if tail is None:
        tail = jnp.zeros((Bsz, W - 1, C), xs.dtype)
    full = jnp.concatenate([tail, xs], axis=1)  # (B, S+W-1, C)
    y = jnp.zeros_like(xs)
    for i in range(W):
        y = y + full[:, i : i + S, :] * w[i]
    y = y + b
    new_tail = full[:, -(W - 1):, :]
    return jax.nn.silu(y), new_tail


def _segsum(logd: jax.Array) -> jax.Array:
    """logd: (..., L) per-step log decay -> (..., L, L) matrix with
    M[t, s] = sum_{r=s+1..t} logd_r for s <= t, -inf above diagonal."""
    L = logd.shape[-1]
    cs = jnp.cumsum(logd, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]  # sum_{r=s+1..t} = cs_t - cs_s
    mask = jnp.tril(jnp.ones((L, L), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(
    u: jax.Array,        # (B, S, nh, hp)  inputs  (dt * x)
    logd: jax.Array,     # (B, S, nh)      per-step log decay (dt * A, <= 0)
    Bm: jax.Array,       # (B, S, G, N)
    Cm: jax.Array,       # (B, S, G, N)
    chunk: int,
    h0: jax.Array | None = None,   # (B, nh, N, hp) initial state
):
    """Chunked SSD.  Returns (y (B,S,nh,hp), h_final (B,nh,N,hp))."""
    Bsz, S, nh, hp = u.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = nh // G
    pad = (-S) % chunk
    if pad:
        u = jnp.pad(u, ((0, 0), (0, pad), (0, 0), (0, 0)))
        logd = jnp.pad(logd, ((0, 0), (0, pad), (0, 0)))  # log a = 0 => identity
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Sp = S + pad
    nc = Sp // chunk
    L = chunk

    uc = u.reshape(Bsz, nc, L, nh, hp)
    dc = logd.reshape(Bsz, nc, L, nh)
    bc = Bm.reshape(Bsz, nc, L, G, N)
    cc = Cm.reshape(Bsz, nc, L, G, N)

    # ---- intra-chunk (quadratic within chunk)
    seg = _segsum(jnp.moveaxis(dc, -1, -2))              # (B,nc,nh,L,L)
    cb = jnp.einsum("bclgn,bcsgn->bcgls", cc, bc)        # (B,nc,G,L,L)
    cb = jnp.repeat(cb, rep, axis=2)                     # (B,nc,nh,L,L)
    m = cb * jnp.exp(seg)
    y_intra = jnp.einsum("bchls,bcshp->bclhp", m, uc)

    # ---- per-chunk summary state: S_c = sum_s exp(l_last - l_s) B_s u_s
    cs = jnp.cumsum(dc, axis=2)                          # (B,nc,L,nh) inclusive
    decay_to_end = jnp.exp(cs[:, :, -1:, :] - cs)        # (B,nc,L,nh)
    b_rep = jnp.repeat(bc, rep, axis=3)                  # (B,nc,L,nh,N)
    s_chunk = jnp.einsum("bclhn,bclh,bclhp->bchnp", b_rep, decay_to_end, uc)

    # ---- inter-chunk recurrence: H_k = A_k H_{k-1} + S_k
    a_chunk = jnp.exp(cs[:, :, -1, :])                   # (B,nc,nh) total decay
    if h0 is None:
        h0 = jnp.zeros((Bsz, nh, N, hp), jnp.float32)

    def combine(e1, e2):
        a1, s1 = e1
        a2, s2 = e2
        return a1 * a2, s2 + a2[..., None, None] * s1

    aa, ss = lax.associative_scan(
        combine, (a_chunk, s_chunk.astype(jnp.float32)), axis=1
    )
    # states *after* each chunk, including h0 influence
    h_after = ss + aa[..., None, None] * h0[:, None]     # (B,nc,nh,N,hp)
    h_before = jnp.concatenate([h0[:, None], h_after[:, :-1]], axis=1)

    # ---- inter-chunk contribution: y_t += C_t . (exp(l_t) * H_before)
    decay_from_start = jnp.exp(cs)                       # (B,nc,L,nh)
    c_rep = jnp.repeat(cc, rep, axis=3)                  # (B,nc,L,nh,N)
    y_inter = jnp.einsum(
        "bclhn,bchnp->bclhp", c_rep, h_before
    ) * decay_from_start[..., None]

    y = (y_intra + y_inter).reshape(Bsz, Sp, nh, hp)[:, :S]
    return y.astype(u.dtype), h_after[:, -1].astype(jnp.float32)


def ssm_forward(
    p: dict,
    x: jax.Array,            # (B, S, d)
    cfg: ModelConfig,
    *,
    valid: jax.Array | None = None,   # (B, S) bool
    conv_tail: jax.Array | None = None,
    h0: jax.Array | None = None,
):
    """Full-sequence Mamba2 block (train / prefill).

    Returns (y (B,S,d), state dict {"ssm": h, "conv": tail}).
    """
    dm = ssm_dims(cfg)
    Bsz, S, _ = x.shape
    if valid is not None:
        x = x * valid[..., None].astype(x.dtype)
    z, xi, bc_in, dt_raw = _proj(p, x, dm)
    tail_x = conv_tail["x"] if conv_tail is not None else None
    tail_bc = conv_tail["bc"] if conv_tail is not None else None
    xc, new_tail_x = _causal_conv(xi, p["conv_x_w"], p["conv_x_b"], tail_x)
    bc, new_tail_bc = _causal_conv(bc_in, p["conv_bc_w"], p["conv_bc_b"], tail_bc)
    gn = dm.n_groups * dm.d_state
    b, c = jnp.split(bc, [gn], axis=-1)
    new_tail = {"x": new_tail_x, "bc": new_tail_bc}

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,S,nh)
    if valid is not None:
        dt = dt * valid[..., None].astype(jnp.float32)
    A = -jnp.exp(p["A_log"])                                          # (nh,)
    logd = dt * A                                                     # (B,S,nh)

    xh = xc.reshape(Bsz, S, dm.n_heads, dm.head_dim).astype(jnp.float32)
    u = xh * dt[..., None]
    bm = b.reshape(Bsz, S, dm.n_groups, dm.d_state).astype(jnp.float32)
    cm = c.reshape(Bsz, S, dm.n_groups, dm.d_state).astype(jnp.float32)

    y, h_final = ssd_chunked(u, logd, bm, cm, dm.chunk, h0)
    y = y + xh * p["D"][:, None]
    y = y.reshape(Bsz, S, dm.d_inner).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    out = y @ p["out_proj"]
    return out, {"ssm": h_final, "conv": new_tail}


def ssm_step(
    p: dict,
    x: jax.Array,            # (B, m, d) new tokens (m small; typically 1)
    cfg: ModelConfig,
    state: dict,             # {"ssm": (B,nh,N,hp), "conv": (B,W-1,conv_dim)}
    *,
    valid: jax.Array | None = None,
):
    """Recurrent decode step (handles m>=1 sequentially within).

    Returns (y (B,m,d), new_state). Discard new_state to "not commit" (probe).
    """
    dm = ssm_dims(cfg)
    Bsz, m, _ = x.shape
    if valid is not None:
        x = x * valid[..., None].astype(x.dtype)
    z, xi, bc_in, dt_raw = _proj(p, x, dm)

    xc2, new_tail_x = _causal_conv(xi, p["conv_x_w"], p["conv_x_b"], state["conv"]["x"])
    bc2, new_tail_bc = _causal_conv(bc_in, p["conv_bc_w"], p["conv_bc_b"], state["conv"]["bc"])
    gn = dm.n_groups * dm.d_state
    b2, c2 = jnp.split(bc2, [gn], axis=-1)
    new_tail = {"x": new_tail_x, "bc": new_tail_bc}
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    if valid is not None:
        dt = dt * valid[..., None].astype(jnp.float32)
    A = -jnp.exp(p["A_log"])

    xh = xc2.reshape(Bsz, m, dm.n_heads, dm.head_dim).astype(jnp.float32)
    bm = b2.reshape(Bsz, m, dm.n_groups, dm.d_state).astype(jnp.float32)
    cm = c2.reshape(Bsz, m, dm.n_groups, dm.d_state).astype(jnp.float32)
    rep = dm.n_heads // dm.n_groups

    def step(h, inp):
        xh_t, bm_t, cm_t, dt_t = inp   # (B,nh,hp), (B,G,N), (B,G,N), (B,nh)
        a_t = jnp.exp(dt_t * A)        # (B,nh)
        b_rep = jnp.repeat(bm_t, rep, axis=1)   # (B,nh,N)
        c_rep = jnp.repeat(cm_t, rep, axis=1)
        h = a_t[..., None, None] * h + jnp.einsum(
            "bhn,bhp,bh->bhnp", b_rep, xh_t, dt_t
        )
        y_t = jnp.einsum("bhn,bhnp->bhp", c_rep, h)
        return h, y_t

    h, ys = lax.scan(
        step,
        state["ssm"],
        (
            jnp.moveaxis(xh, 1, 0),
            jnp.moveaxis(bm, 1, 0),
            jnp.moveaxis(cm, 1, 0),
            jnp.moveaxis(dt, 1, 0),
        ),
    )
    y = jnp.moveaxis(ys, 0, 1) + xh * p["D"][:, None]   # (B,m,nh,hp)
    y = y.reshape(Bsz, m, dm.d_inner).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    out = y @ p["out_proj"]
    return out, {"ssm": h, "conv": new_tail}


def ssm_state_init(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> dict:
    dm = ssm_dims(cfg)
    gn = dm.n_groups * dm.d_state
    return {
        "ssm": jnp.zeros((batch, dm.n_heads, dm.d_state, dm.head_dim), jnp.float32),
        "conv": {
            "x": jnp.zeros((batch, dm.conv_width - 1, dm.d_inner), dtype),
            "bc": jnp.zeros((batch, dm.conv_width - 1, 2 * gn), dtype),
        },
    }
