"""Shared model building blocks: norms, RoPE / M-RoPE, MLPs, embeddings.

Everything is a pure function over explicit parameter pytrees (dicts of
jnp arrays) — no Module framework.  Layer parameters are later *stacked*
along a leading axis and driven by ``lax.scan`` so the lowered HLO stays
small for 60-layer models (see DESIGN.md §10).
"""
from __future__ import annotations

import math
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

# ----------------------------------------------------------------- init


def uniform_scale_init(key, shape, scale, dtype):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale / math.sqrt(fan_in)
    return (jax.random.normal(key, shape) * std).astype(dtype)


def dense_init(key, in_dim, out_dim, dtype, scale=1.0):
    return uniform_scale_init(key, (in_dim, out_dim), scale, dtype)


# ----------------------------------------------------------------- norms


def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-6, one_plus: bool = False) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    scale = (1.0 + w.astype(jnp.float32)) if one_plus else w.astype(jnp.float32)
    return (x * scale).astype(dt)


def rmsnorm_init(d: int, dtype, one_plus: bool = False):
    # gemma stores (1+w); init w=0 <=> scale 1
    return jnp.zeros((d,), dtype) if one_plus else jnp.ones((d,), dtype)


# ----------------------------------------------------------------- rope


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D); positions: broadcastable to (..., S) int32.

    Uses the half-rotation ("rotate_half", llama) convention.
    """
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # (d/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, d/2)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, d/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(
    x: jax.Array, positions3: jax.Array, theta: float, sections: Sequence[int]
) -> jax.Array:
    """Qwen2-VL M-RoPE.  positions3: (..., S, 3) = (t, h, w) position ids.

    The head_dim/2 frequency slots are partitioned into ``sections``
    (t, h, w); each section takes its angle from the corresponding position
    stream.  For pure text, t==h==w and this reduces to ordinary RoPE.
    """
    d = x.shape[-1]
    assert sum(sections) == d // 2, (sections, d)
    freqs = rope_freqs(d, theta)  # (d/2,)
    sec_id = jnp.repeat(
        jnp.arange(len(sections)), jnp.array(sections), total_repeat_length=d // 2
    )  # (d/2,) in {0,1,2}
    pos = positions3.astype(jnp.float32)[..., sec_id]  # (..., S, d/2)
    angles = pos * freqs  # (..., S, d/2)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------- mlp


def mlp_init(key, cfg: ModelConfig, d_ff: int, dtype, d_in: int | None = None) -> dict:
    d = d_in or cfg.d_model
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "w_up": dense_init(k1, d, d_ff, dtype),
        "w_down": dense_init(k2, d_ff, cfg.d_model, dtype),
    }
    if cfg.activation in ("silu", "geglu"):
        p["w_gate"] = dense_init(k3, d, d_ff, dtype)
    return p


def mlp_apply(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    up = x @ p["w_up"]
    if cfg.activation == "silu":
        h = jax.nn.silu(x @ p["w_gate"]) * up
    elif cfg.activation == "geglu":
        h = jax.nn.gelu(x @ p["w_gate"], approximate=True) * up
    else:  # gelu
        h = jax.nn.gelu(up, approximate=True)
    return h @ p["w_down"]


# ----------------------------------------------------------------- embed


def embed_init(key, cfg: ModelConfig, dtype) -> dict:
    k1, k2 = jax.random.split(key)
    v = cfg.padded_vocab
    p = {"embedding": (jax.random.normal(k1, (v, cfg.d_model)) * 0.02).astype(dtype)}
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(k2, cfg.d_model, v, dtype)
    return p


def embed_apply(p: dict, tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    x = p["embedding"][tokens]
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return x


def lm_head_apply(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    w = p["embedding"].T if cfg.tie_embeddings else p["lm_head"]
    logits = x @ w
    if cfg.logit_softcap:
        c = cfg.logit_softcap
        logits = jnp.tanh(logits / c) * c
    return logits
