"""Fine-grained MoE (DeepSeek-style): shared experts + routed top-k experts,
expert-parallel over the ``model`` mesh axis via ``shard_map``.

Design (DESIGN.md §4.3/§7):

* The router runs in plain jit (weights replicated, tokens data-sharded).
* Routed expert weights live 2D-sharded at rest — experts over ``model``,
  d_model over ``data`` (FSDP) — because DeepSeek-V2's 160x60 experts are
  the bulk of 236B parameters and must be cut 256 ways to fit HBM.
* The expert compute runs inside ``shard_map``: activations are replicated
  over the model axis (they are only batch-sharded), each device gathers the
  tokens routed to its E/model_size local experts into a capacity-bounded
  buffer (GShard position-in-expert via cumsum — no sort), runs the expert
  FFNs as one batched matmul, scatter-adds weighted outputs, and ``psum``s
  over the model axis.  The psum replaces the tensor-parallel MLP's usual
  all-reduce, so expert parallelism adds no extra collective phase.
* Capacity: dropless (C = T_local) when T_local*k is small (decode/probe —
  inference must not drop tokens), else ceil(T_local*k*cf/E) (train/prefill,
  standard GShard behavior; dropped tokens pass through the residual).

Single-device path (ctx.mesh is None) runs the identical dispatch code with
E_local = E — used by CPU tests.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.utils.jax_compat import shard_map
from repro.models.common import dense_init, mlp_apply, mlp_init
from repro.sharding.partition import ShardCtx


def moe_init(key, cfg: ModelConfig, dtype) -> dict:
    mo = cfg.moe
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    ks_up = jax.random.split(k2, mo.n_routed)
    ks_gate = jax.random.split(k3, mo.n_routed)
    ks_down = jax.random.split(k4, mo.n_routed)
    p: dict = {
        "router": dense_init(k1, cfg.d_model, mo.n_routed, jnp.float32),
        "experts": {
            "w_up": jax.vmap(lambda k: dense_init(k, cfg.d_model, mo.d_expert, dtype))(ks_up),
            "w_gate": jax.vmap(lambda k: dense_init(k, cfg.d_model, mo.d_expert, dtype))(ks_gate),
            "w_down": jax.vmap(lambda k: dense_init(k, mo.d_expert, cfg.d_model, dtype))(ks_down),
        },
    }
    if mo.n_shared:
        p["shared"] = mlp_init(k5, cfg, mo.d_expert * mo.n_shared, dtype)
    return p


def router_topk(p: dict, x: jax.Array, cfg: ModelConfig):
    """x: (B,S,d) -> (weights (B,S,k), ids (B,S,k), aux_loss scalar)."""
    mo = cfg.moe
    logits = x.astype(jnp.float32) @ p["router"]          # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = lax.top_k(probs, mo.top_k)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)
    topw = topw * mo.routed_scale

    # load-balance aux loss (Switch/DeepSeek): E * sum_e f_e * P_e
    E = mo.n_routed
    onehot = jax.nn.one_hot(topi, E, dtype=jnp.float32)    # (B,S,k,E)
    f = onehot.sum(axis=(0, 1, 2)) / (onehot.sum() + 1e-9)  # dispatch fraction
    pbar = probs.mean(axis=(0, 1))
    aux = E * jnp.sum(f * pbar)
    return topw, topi, aux


def _capacity(t_local: int, k: int, n_experts: int, cf: float) -> int:
    if t_local * k <= 4096:          # decode / small prefill: dropless
        return t_local
    return int(math.ceil(t_local * k * cf / n_experts))


def _expert_compute(x, topw, topi, w_up, w_gate, w_down, *, cfg: ModelConfig,
                    e0, n_local, cap, model_axis: str | None,
                    combine: str = "psum_f32"):
    """Local expert dispatch+compute.  x: (T,d); topw/topi: (T,k);
    w_*: (n_local, ...) local expert slices.  Returns (T,d) partial output
    (needs psum over model axis when sharded — done by caller)."""
    T, d = x.shape
    k = topi.shape[-1]
    pair_e = topi.reshape(T * k)
    pair_w = topw.reshape(T * k)
    pair_t = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)

    local = pair_e - e0
    in_range = (local >= 0) & (local < n_local)
    onehot = (local[:, None] == jnp.arange(n_local)[None, :]) & in_range[:, None]
    pos = jnp.cumsum(onehot.astype(jnp.int32), axis=0) - 1       # (T*k, n_local)
    pos_own = jnp.sum(pos * onehot, axis=-1)                      # (T*k,)
    keep = in_range & (pos_own < cap)
    slot = jnp.where(keep, jnp.clip(local, 0, n_local - 1) * cap + pos_own, n_local * cap)

    buf_tok = jnp.full((n_local * cap + 1,), T, jnp.int32).at[slot].set(pair_t, mode="drop")
    buf_w = jnp.zeros((n_local * cap + 1,), jnp.float32).at[slot].set(pair_w, mode="drop")
    buf_tok, buf_w = buf_tok[:-1], buf_w[:-1]

    x_pad = jnp.concatenate([x, jnp.zeros((1, d), x.dtype)], axis=0)
    xg = x_pad[buf_tok].reshape(n_local, cap, d)

    h_up = jnp.einsum("ecd,edf->ecf", xg, w_up)
    if cfg.activation in ("silu", "geglu"):
        h_gate = jnp.einsum("ecd,edf->ecf", xg, w_gate)
        act = jax.nn.silu if cfg.activation == "silu" else functools.partial(
            jax.nn.gelu, approximate=True
        )
        h = act(h_gate) * h_up
    else:
        h = jax.nn.gelu(h_up, approximate=True)
    yg = jnp.einsum("ecf,efd->ecd", h, w_down)                   # (E_l, cap, d)

    yflat = yg.reshape(n_local * cap, d) * buf_w[:, None].astype(yg.dtype)
    out = jnp.zeros((T + 1, d), yg.dtype).at[buf_tok].add(yflat)[:T]
    if model_axis is not None:
        if combine == "psum_bf16":
            out = lax.psum(out.astype(jnp.bfloat16), model_axis)
        elif combine == "scatter":
            pass  # caller reduce-scatters over the sequence dim
        else:
            out = lax.psum(out, model_axis)
    return out


def moe_apply(
    p: dict,
    x: jax.Array,            # (B, S, d)
    cfg: ModelConfig,
    ctx: ShardCtx,
) -> tuple[jax.Array, jax.Array]:
    """Returns (y (B,S,d), aux_loss scalar)."""
    mo = cfg.moe
    B, S, d = x.shape
    topw, topi, aux = router_topk(p, x, cfg)

    if ctx.mesh is None or ctx.model_size == 1:
        cap = _capacity(B * S, mo.top_k, mo.n_routed, mo.capacity_factor)
        y = _expert_compute(
            x.reshape(B * S, d), topw.reshape(B * S, -1), topi.reshape(B * S, -1),
            p["experts"]["w_up"], p["experts"]["w_gate"], p["experts"]["w_down"],
            cfg=cfg, e0=0, n_local=mo.n_routed, cap=cap, model_axis=None,
        ).reshape(B, S, d)
    else:
        ms = ctx.model_size
        n_local = mo.n_routed // ms
        # batch=1 shapes (long_500k) cannot shard batch over data: replicate
        batch_shardable = B % ctx.data_size == 0
        t_local = (B // ctx.data_size) * S if batch_shardable else B * S
        cap = _capacity(t_local, mo.top_k, mo.n_routed, mo.capacity_factor)
        bspec = ctx.batch_spec_entry() if batch_shardable else None
        m = ctx.model_axis

        # FSDP re-gather of the d_model shards (transient, per layer)
        w_up = ctx.wsc(p["experts"]["w_up"], P(m, None, None))
        w_gate = ctx.wsc(p["experts"]["w_gate"], P(m, None, None))
        w_down = ctx.wsc(p["experts"]["w_down"], P(m, None, None))

        combine = ctx.moe_combine
        if combine == "scatter" and (S % ms != 0 or B * S < ms):
            combine = "psum_bf16"   # decode/probe steps: too few tokens

        def local_fn(xl, twl, til, wu, wg, wd):
            Bl, Sl, dl = xl.shape
            e0 = lax.axis_index(m) * n_local
            y = _expert_compute(
                xl.reshape(Bl * Sl, dl), twl.reshape(Bl * Sl, -1),
                til.reshape(Bl * Sl, -1), wu, wg, wd,
                cfg=cfg, e0=e0, n_local=n_local, cap=cap, model_axis=m,
                combine=combine,
            )
            y = y.reshape(Bl, Sl, dl)
            if combine == "scatter":
                # bf16 reduce-scatter over the sequence dim: each model rank
                # keeps its S/ms slice — exactly the sequence-parallel
                # residual layout, so the following residual add needs no
                # re-shard.
                y = lax.psum_scatter(
                    y.astype(jnp.bfloat16), m, scatter_dimension=1, tiled=True
                )
            return y

        out_spec = (P(bspec, m, None) if combine == "scatter"
                    else P(bspec, None, None))
        y = shard_map(
            local_fn,
            mesh=ctx.mesh,
            in_specs=(
                P(bspec, None, None),
                P(bspec, None, None),
                P(bspec, None, None),
                P(m, None, None),
                P(m, None, None),
                P(m, None, None),
            ),
            out_specs=out_spec,
            check_vma=False,
        )(x, topw, topi, w_up, w_gate, w_down)
        y = y.astype(x.dtype)

    if mo.n_shared:
        y = y + mlp_apply(p["shared"], x, cfg)
    return y, aux
