"""Model facade: embedding glue, losses, prefill/decode/probe entry points.

A ``Model`` is stateless — parameters are explicit pytrees; methods are pure
functions suitable for ``jax.jit`` with in/out shardings.  The EAT probe
(``probe_entropy``) is a first-class serving operation: a forward over the
probe tokens (``</think>`` [+ prefix]) against the live cache whose returned
cache is *discarded*, followed by the fused entropy kernel.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.kernels.entropy_probe.ops import next_token_entropy
from repro.models import transformer as tfm
from repro.models.transformer import write_slots
from repro.models.common import embed_apply, embed_init, lm_head_apply
from repro.sharding.partition import ShardCtx


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    ctx: ShardCtx = ShardCtx()
    attn_impl: str = "auto"
    unroll: bool = False      # unroll layer scans (dry-run cost probes only)
    # decode/probe attention over a serving cache (kernels/paged_attention):
    #   "gather"            — classic: paged caches materialize the gathered
    #                         logical view, ring caches read densely
    #   "auto"/"xla"/"pallas" — page-native: paged caches read K/V straight
    #                         off the pools through the compacted page list
    #                         (O(mapped pages) per token); ring caches run
    #                         the same block-sequential algorithm, so the
    #                         two backends stay bit-identical per impl.
    # ``paged_attn_page`` is the ring comparator's block size — it must
    # match the paged cache's CacheConfig.page_size for the bit-exactness
    # A/B (the engine threads both from EngineConfig.cache).
    paged_attn_impl: str = "gather"
    paged_attn_page: int = 16

    # ---------------------------------------------------------------- init
    def init(self, key) -> dict:
        k1, k2 = jax.random.split(key)
        dtype = jnp.dtype(self.cfg.dtype)
        return {
            "embed": embed_init(k1, self.cfg, dtype),
            "stack": tfm.init_stack(k2, self.cfg, dtype),
        }

    # ---------------------------------------------------------------- embed
    def embed_stream(self, params, tokens, image_embeds=None) -> jax.Array:
        """Token embeddings; VLM prepends stub patch embeddings."""
        x = embed_apply(params["embed"], tokens, self.cfg)
        if self.cfg.arch_type == "vlm" and image_embeds is not None:
            x = jnp.concatenate([image_embeds.astype(x.dtype), x], axis=1)
        return x

    def unembed_matrix(self, params) -> jax.Array:
        e = params["embed"]
        return e["embedding"].T if self.cfg.tie_embeddings else e["lm_head"]

    def logits(self, params, hidden) -> jax.Array:
        return lm_head_apply(params["embed"], hidden, self.cfg)

    # ---------------------------------------------------------------- train
    def train_loss(self, params, batch: dict, *, remat: bool = True,
                   z_loss: float = 1e-4, window: int | None = None):
        """batch keys: tokens (B,S); targets, loss_mask (B,S_total);
        positions (B,S_total[,3]); pos1d (B,S_total); [frames (B,T,d)];
        [image_embeds (B,P,d)].  Returns (loss, metrics dict)."""
        cfg, ctx = self.cfg, self.ctx
        window = cfg.sliding_window if window is None else window
        x = self.embed_stream(params, batch["tokens"], batch.get("image_embeds"))
        pos = batch["positions"]
        pos1d = batch["pos1d"]

        enc_out = enc_pos = None
        if cfg.arch_type == "encdec":
            frames = batch["frames"]
            Bf, T, _ = frames.shape
            enc_pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (Bf, T))
            enc_out = tfm.encode(
                params["stack"], frames.astype(x.dtype), enc_pos, cfg, ctx,
                attn_impl=self.attn_impl, remat=remat, unroll=self.unroll,
            )

        hidden, aux = tfm.forward_train(
            params["stack"], x, pos, pos1d, cfg, ctx,
            valid=pos1d >= 0, enc_out=enc_out, enc_pos=enc_pos,
            attn_impl=self.attn_impl, remat=remat, window=window,
            unroll=self.unroll,
        )
        logits = self.logits(params, hidden)
        if ctx.mesh is not None:
            logits = ctx.wsc(logits, P(ctx.batch_spec_entry(), None, ctx.model_axis))
        loss, metrics = cross_entropy_loss(
            logits, batch["targets"], batch["loss_mask"], cfg.vocab, z_loss=z_loss
        )
        if cfg.moe is not None:
            loss = loss + cfg.moe.router_aux_weight * aux
            metrics["aux_loss"] = aux
        metrics["loss"] = loss
        return loss, metrics

    # ---------------------------------------------------------------- serve
    def prefill(self, params, tokens, positions, pos1d, cache, *,
                frames=None, image_embeds=None, window: int | None = None):
        """Fill the cache with the prompt; returns (hidden (B,S,d), cache)."""
        cfg, ctx = self.cfg, self.ctx
        window = cfg.sliding_window if window is None else window
        x = self.embed_stream(params, tokens, image_embeds)
        cache = dict(cache)

        if cfg.arch_type == "encdec":
            Bf, T, _ = frames.shape
            enc_pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (Bf, T))
            enc_out = tfm.encode(
                params["stack"], frames.astype(x.dtype), enc_pos, cfg, ctx,
                attn_impl=self.attn_impl, unroll=self.unroll,
            )
            from repro.models.attention import cross_attn_kv

            ck, cv = jax.vmap(lambda p: cross_attn_kv(p, enc_out, cfg))(
                params["stack"]["dec_layers"]["cross"]
            )
            layers = dict(cache["layers"])
            dec = dict(layers["dec_seg"])
            dec["ck"], dec["cv"] = ck.astype(dec["ck"].dtype), cv.astype(dec["cv"].dtype)
            layers["dec_seg"] = dec
            cache["layers"] = layers
            cache["enc_pos"] = enc_pos

        m = x.shape[1]
        capacity = cache["pos"].shape[1]
        slots = write_slots(cache["cur"], m, capacity)
        hidden, cache, _ = tfm.forward_cached(
            params["stack"], x, positions, pos1d, slots, cache, cfg, ctx,
            attn_impl=self.attn_impl, window=window, unroll=self.unroll,
            paged_impl=self.paged_attn_impl, page_block=self.paged_attn_page,
        )
        return hidden, cache

    def decode_step(self, params, tokens, positions, pos1d, cache, *,
                    window: int | None = None):
        """One decode step (m new tokens, usually 1).
        Returns (logits (B,m,Vp), cache)."""
        cfg, ctx = self.cfg, self.ctx
        window = cfg.sliding_window if window is None else window
        x = self.embed_stream(params, tokens)
        capacity = cache["pos"].shape[1]
        slots = write_slots(cache["cur"], x.shape[1], capacity)
        hidden, cache, _ = tfm.forward_cached(
            params["stack"], x, positions, pos1d, slots, cache, cfg, ctx,
            attn_impl=self.attn_impl, window=window, unroll=self.unroll,
            paged_impl=self.paged_attn_impl, page_block=self.paged_attn_page,
        )
        return self.logits(params, hidden), cache

    def decode_and_probe(self, params, token, positions, pos1d, cache,
                         probe_tokens, *, window: int | None = None,
                         entropy_impl: str = "auto", interpret: bool = False):
        """Fused serve step (§Perf): ONE forward over [token, probe...]
        instead of decode + separate probe — halves the per-step weight
        traffic (under FSDP: one all-gather instead of two).

        Commits only the decode token: ``cur`` advances by 1; the probe
        K/V land in the next slots and are masked by position until
        overwritten (future q positions < stale probe positions).  With a
        ring-buffer (sliding-window) cache the probe writes sacrifice the
        len(probe) oldest window slots — window is effectively W-m.

        token: (B,1); probe_tokens: (B,m).  Returns (logits (B,1,Vp),
        eat (B,), cache).

        SSM/hybrid states are *cumulative* (not slot-addressed), so a fused
        commit would bake the probe into the recurrence — those arch types
        transparently fall back to the separate decode + non-committing
        probe (same signature, no fusion win).
        """
        cfg, ctx = self.cfg, self.ctx
        if cfg.arch_type in ("ssm", "hybrid"):
            logits, cache = self.decode_step(
                params, token, positions[:, :1], pos1d[:, :1], cache, window=window
            )
            m = probe_tokens.shape[1]
            eat = self.probe_entropy(
                params, probe_tokens, positions[:, 1:1 + m], pos1d[:, 1:1 + m],
                cache, window=window, entropy_impl=entropy_impl,
                interpret=interpret,
            )
            return logits, eat, cache
        window = cfg.sliding_window if window is None else window
        toks = jnp.concatenate([token, probe_tokens], axis=1)
        x = self.embed_stream(params, toks)
        capacity = cache["pos"].shape[1]
        slots = write_slots(cache["cur"], x.shape[1], capacity)
        hidden, new_cache, _ = tfm.forward_cached(
            params["stack"], x, positions, pos1d, slots, cache, cfg, ctx,
            attn_impl=self.attn_impl, window=window, unroll=self.unroll,
            paged_impl=self.paged_attn_impl, page_block=self.paged_attn_page,
        )
        new_cache["cur"] = cache["cur"] + 1            # commit decode only
        logits = self.logits(params, hidden[:, :1])
        w = self.unembed_matrix(params)
        eat = next_token_entropy(
            hidden[:, -1], w, cfg.vocab, impl=entropy_impl, interpret=interpret
        )
        return logits, eat, new_cache

    def probe_entropy(self, params, probe_tokens, positions, pos1d, cache, *,
                      window: int | None = None, entropy_impl: str = "auto",
                      interpret: bool = False):
        """EAT (paper Eq. 5/13): run the probe tokens (``</think>`` + optional
        prefix) against the cache WITHOUT committing it, and return the
        next-token entropy at the last probe position.  (B,) float32 nats."""
        cfg, ctx = self.cfg, self.ctx
        window = cfg.sliding_window if window is None else window
        x = self.embed_stream(params, probe_tokens)
        capacity = cache["pos"].shape[1]
        slots = write_slots(cache["cur"], x.shape[1], capacity)
        hidden, _discarded, _ = tfm.forward_cached(
            params["stack"], x, positions, pos1d, slots, cache, cfg, ctx,
            attn_impl=self.attn_impl, window=window, unroll=self.unroll,
            paged_impl=self.paged_attn_impl, page_block=self.paged_attn_page,
        )
        h_last = hidden[:, -1]
        w = self.unembed_matrix(params)
        return next_token_entropy(
            h_last, w, cfg.vocab, impl=entropy_impl, interpret=interpret
        )


def cross_entropy_loss(logits, targets, mask, vocab: int, *, z_loss: float = 1e-4):
    """Masked CE over the valid vocabulary (padding columns excluded).

    Uses the one-hot-contraction form (SPMD-friendly over a vocab-sharded
    logits tensor) + MaxText-style z-loss on log Z.
    """
    Vp = logits.shape[-1]
    lf = logits.astype(jnp.float32)
    col_valid = jnp.arange(Vp) < vocab
    lf = jnp.where(col_valid, lf, -1e30)
    m = jax.lax.stop_gradient(lf.max(-1, keepdims=True))
    shifted = lf - m
    logz = jnp.log(jnp.exp(shifted).sum(-1))         # (B,S)
    onehot = jax.nn.one_hot(targets, Vp, dtype=jnp.float32)
    ll = (shifted * onehot).sum(-1) - logz           # log p[target]
    maskf = mask.astype(jnp.float32)
    denom = jnp.maximum(maskf.sum(), 1.0)
    ce = -(ll * maskf).sum() / denom
    zl = ((logz + m[..., 0]) ** 2 * maskf).sum() / denom
    loss = ce + z_loss * zl
    acc = ((lf.argmax(-1) == targets) * maskf).sum() / denom
    return loss, {"ce": ce, "z_loss": zl, "accuracy": acc, "tokens": maskf.sum()}
