"""Attention projections: GQA/MQA/MHA (+qk-norm, bias, RoPE/M-RoPE) and
DeepSeek-V2 MLA (multi-head latent attention, cache-the-latent form).

The attention *math* (masking, online softmax, GQA head grouping) lives in
``repro.kernels.flash_attention.ops.attention``; this module owns parameter
layout, rotary embedding, and the KV-representation contract with the cache:

* GQA layers cache ``k, v``: (B, S, Hkv, hd) each.
* MLA layers cache ``c``: (B, S, kv_lora) latent + ``k_rope``: (B, S, rope_d)
  — *not* the expanded per-head K/V (that is MLA's point; see DESIGN.md §5).
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels.flash_attention.ops import attention
from repro.models.common import apply_mrope, apply_rope, dense_init, rmsnorm


# --------------------------------------------------------------- GQA


def gqa_init(key, cfg: ModelConfig, dtype, d_in: int | None = None) -> dict:
    d = d_in or cfg.d_model
    hd = cfg.resolved_head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "wq": dense_init(k1, d, cfg.n_heads * hd, dtype),
        "wk": dense_init(k2, d, cfg.n_kv_heads * hd, dtype),
        "wv": dense_init(k3, d, cfg.n_kv_heads * hd, dtype),
        "wo": dense_init(k4, cfg.n_heads * hd, cfg.d_model, dtype),
    }
    if cfg.attn_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * hd,), dtype)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * hd,), dtype)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * hd,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def _maybe_rope(x, positions, cfg: ModelConfig):
    if cfg.mrope_sections:
        # positions: (B, S, 3)
        return apply_mrope(x, positions, cfg.rope_theta, cfg.mrope_sections)
    return apply_rope(x, positions, cfg.rope_theta)


def gqa_qkv(p: dict, x: jax.Array, positions: jax.Array, cfg: ModelConfig):
    """x: (B, S, d) -> q (B,S,Hq,hd), k,v (B,S,Hkv,hd) with RoPE applied."""
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.attn_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, cfg.n_heads, hd)
    k = k.reshape(B, S, cfg.n_kv_heads, hd)
    v = v.reshape(B, S, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    q = _maybe_rope(q, positions, cfg)
    k = _maybe_rope(k, positions, cfg)
    return q, k, v


def gqa_out(p: dict, attn: jax.Array) -> jax.Array:
    B, S = attn.shape[:2]
    return attn.reshape(B, S, -1) @ p["wo"]


def attn_scale(cfg: ModelConfig) -> float:
    if cfg.attn_temperature:
        return cfg.attn_temperature
    if cfg.mla is not None:
        return 1.0 / math.sqrt(cfg.mla.qk_nope_head_dim + cfg.mla.qk_rope_head_dim)
    return 1.0 / math.sqrt(cfg.resolved_head_dim)


def gqa_self_attention(
    p: dict,
    x: jax.Array,
    positions: jax.Array,      # (B,S) or (B,S,3) for mrope
    pos1d: jax.Array,          # (B,S) int32 scalar positions for masking
    cfg: ModelConfig,
    *,
    causal: bool = True,
    window: int = 0,
    attn_impl: str = "auto",
) -> tuple[jax.Array, tuple[jax.Array, jax.Array]]:
    """Full-sequence self attention (train / prefill / encoder).
    Returns (y, (k, v))."""
    q, k, v = gqa_qkv(p, x, positions, cfg)
    o = attention(
        q, k, v, pos1d, pos1d, causal=causal, window=window,
        scale=attn_scale(cfg), impl=attn_impl,
    )
    return gqa_out(p, o), (k, v)


# --------------------------------------------------------------- MLA


def mla_init(key, cfg: ModelConfig, dtype) -> dict:
    m = cfg.mla
    d = cfg.d_model
    qk_hd = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 7)
    return {
        "w_dq": dense_init(ks[0], d, m.q_lora_rank, dtype),
        "q_norm": jnp.ones((m.q_lora_rank,), dtype),
        "w_uq": dense_init(ks[1], m.q_lora_rank, cfg.n_heads * qk_hd, dtype),
        "w_dkv": dense_init(ks[2], d, m.kv_lora_rank, dtype),
        "kv_norm": jnp.ones((m.kv_lora_rank,), dtype),
        "w_kr": dense_init(ks[3], d, m.qk_rope_head_dim, dtype),
        "w_uk": dense_init(ks[4], m.kv_lora_rank, cfg.n_heads * m.qk_nope_head_dim, dtype),
        "w_uv": dense_init(ks[5], m.kv_lora_rank, cfg.n_heads * m.v_head_dim, dtype),
        "wo": dense_init(ks[6], cfg.n_heads * m.v_head_dim, d, dtype),
    }


def mla_latent(p: dict, x: jax.Array, positions: jax.Array, cfg: ModelConfig):
    """Compute the cacheable latent: c (B,S,r) and rope key (B,S,1,rope_d)."""
    m = cfg.mla
    c = rmsnorm(x @ p["w_dkv"], p["kv_norm"], cfg.norm_eps)
    k_rope = (x @ p["w_kr"])[:, :, None, :]  # single shared rope head
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)
    return c, k_rope[:, :, 0, :]


def mla_q(p: dict, x: jax.Array, positions: jax.Array, cfg: ModelConfig):
    m = cfg.mla
    B, S, _ = x.shape
    q = rmsnorm(x @ p["w_dq"], p["q_norm"], cfg.norm_eps) @ p["w_uq"]
    q = q.reshape(B, S, cfg.n_heads, m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_nope, q_rope = q[..., : m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def mla_self_attention(
    p: dict,
    x: jax.Array,
    positions: jax.Array,
    pos1d: jax.Array,
    cfg: ModelConfig,
    *,
    window: int = 0,
    attn_impl: str = "auto",
):
    """Full-sequence MLA (train / prefill), *expanded* form: per-head K/V are
    materialized transiently (cheaper than the absorbed form when Sq == Skv).
    Returns (y, (c, k_rope)) — the cacheable latent for decode.
    """
    m = cfg.mla
    B, S, _ = x.shape
    q_nope, q_rope = mla_q(p, x, positions, cfg)
    c, k_rope = mla_latent(p, x, positions, cfg)
    k_nope = (c @ p["w_uk"]).reshape(B, S, cfg.n_heads, m.qk_nope_head_dim)
    v = (c @ p["w_uv"]).reshape(B, S, cfg.n_heads, m.v_head_dim)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], q_rope.shape[:2] + (cfg.n_heads, m.qk_rope_head_dim))],
        axis=-1,
    )
    o = attention(
        q, k, v, pos1d, pos1d, causal=True, window=window,
        scale=attn_scale(cfg), impl=attn_impl,
    )
    y = o.reshape(B, S, -1) @ p["wo"]
    return y, (c, k_rope)


def mla_absorbed_attend(
    p: dict,
    q_nope: jax.Array,        # (B, m, H, nope)
    q_rope: jax.Array,        # (B, m, H, rope_d)
    pos1d: jax.Array,         # (B, m)
    cfg: ModelConfig,
    cache_c: jax.Array,       # (B, C, r) latent cache (already contains new)
    cache_kr: jax.Array,      # (B, C, rope_d)
    kv_pos: jax.Array,        # (B, C)
    *,
    window: int = 0,
    attn_impl: str = "auto",
    ctx=None,
) -> jax.Array:
    """Decode/probe MLA in the *absorbed* form: attention runs directly over
    the latent cache as MQA with head_dim r+rope_d and v_dim r.

      score_h = (q_nope_h W_uk_h) . c  +  q_rope_h . k_rope
      out_h   = (attn . c) W_uv_h

    Returns y (B, m, d) — already through the output projection.
    """
    m = cfg.mla
    B, S = q_nope.shape[:2]
    w_uk = p["w_uk"].reshape(m.kv_lora_rank, cfg.n_heads, m.qk_nope_head_dim)
    q_eff = jnp.einsum("bshd,rhd->bshr", q_nope, w_uk)          # (B,m,H,r)
    q_cat = jnp.concatenate([q_eff, q_rope], axis=-1)           # (B,m,H,r+rope)
    k_cat = jnp.concatenate([cache_c, cache_kr], axis=-1)[:, :, None, :]  # MQA
    v_lat = cache_c[:, :, None, :]
    if ctx is not None and use_seq_sharded_cache(cfg, ctx, q_cat.shape[1]):
        o_lat = seq_sharded_decode_attention(
            q_cat, k_cat, v_lat, pos1d, kv_pos, ctx, window=window,
            scale=attn_scale(cfg),
        )
    else:
        o_lat = attention(
            q_cat, k_cat, v_lat, pos1d, kv_pos, causal=True, window=window,
            scale=attn_scale(cfg), impl=attn_impl,
        )  # (B,m,H,r)
    w_uv = p["w_uv"].reshape(m.kv_lora_rank, cfg.n_heads, m.v_head_dim)
    o = jnp.einsum("bshr,rhd->bshd", o_lat, w_uv)
    return o.reshape(B, S, -1) @ p["wo"]


# ------------------------------------------------- seq-sharded decode attn


def use_seq_sharded_cache(cfg: ModelConfig, ctx, m: int) -> bool:
    """True when the KV cache is capacity(S)-sharded over the model axis
    (kv heads not divisible / MLA latent — see serving.cache.cache_pspecs)
    and the query side is a decode/probe (m small).  In that regime GSPMD
    would all-gather the whole cache per attention read (§Perf P1' finding:
    4.3 GB/layer/step for qwen3 decode_32k); the shard_map partial-softmax
    path below reduces the collective to a few hundred KB."""
    return (
        ctx is not None and ctx.mesh is not None and m <= 8
        and (cfg.mla is not None or cfg.n_kv_heads % ctx.model_size != 0)
    )


def seq_sharded_decode_attention(
    q: jax.Array,       # (B, m, Hq, Dk)  replicated over the model axis
    k: jax.Array,       # (B, C, Hkv, Dk) C sharded over the model axis
    v: jax.Array,       # (B, C, Hkv, Dv)
    q_pos: jax.Array,   # (B, m)
    kv_pos: jax.Array,  # (B, C)  C sharded like k/v
    ctx,                # ShardCtx
    *,
    window: int = 0,
    scale: float,
) -> jax.Array:         # (B, m, Hq, Dv)
    """Flash-decode over a sequence-sharded cache: each model rank computes
    (max, sumexp, acc) over its C/ms slice; combine with pmax + psum of the
    tiny per-query stats — no cache movement."""
    from jax.sharding import PartitionSpec as P

    from repro.utils.jax_compat import shard_map

    B, m, Hq, Dk = q.shape
    Hkv = k.shape[2]
    g = Hq // Hkv
    b = ctx.batch_spec_entry() if B % ctx.data_size == 0 else None
    ax = ctx.model_axis

    def local(qL, kL, vL, qpL, kpL):
        qf = qL.astype(jnp.float32) * scale
        kf = jnp.repeat(kL.astype(jnp.float32), g, axis=2)
        vf = jnp.repeat(vL.astype(jnp.float32), g, axis=2)
        s = jnp.einsum("bqhd,bkhd->bhqk", qf, kf)      # (Bl,Hq,m,C_loc)
        valid = kpL[:, None, None, :] >= 0
        valid &= kpL[:, None, None, :] <= qpL[:, None, :, None]
        if window:
            valid &= (qpL[:, None, :, None] - kpL[:, None, None, :]) < window
        s = jnp.where(valid, s, -jnp.inf)
        mx = jnp.max(s, axis=-1)                        # (Bl,Hq,m)
        M = jax.lax.pmax(mx, ax)
        M_safe = jnp.where(jnp.isfinite(M), M, 0.0)
        p = jnp.where(valid, jnp.exp(s - M_safe[..., None]), 0.0)
        l = jax.lax.psum(jnp.sum(p, axis=-1), ax)       # (Bl,Hq,m)
        acc = jax.lax.psum(jnp.einsum("bhqk,bkhd->bhqd", p, vf), ax)
        out = jnp.where(l[..., None] > 0, acc / jnp.maximum(l[..., None], 1e-30), 0.0)
        return out.transpose(0, 2, 1, 3)                # (Bl,m,Hq,Dv)

    out = shard_map(
        local,
        mesh=ctx.mesh,
        in_specs=(
            P(b, None, None, None),
            P(b, ax, None, None),
            P(b, ax, None, None),
            P(b, None),
            P(b, ax),
        ),
        out_specs=P(b, None, None, None),
        check_vma=False,
    )(q, k, v, q_pos, kv_pos)
    return out.astype(q.dtype)


# --------------------------------------------------------------- cross-attn


def cross_attn_init(key, cfg: ModelConfig, dtype) -> dict:
    return gqa_init(key, cfg, dtype)


def cross_attention(
    p: dict,
    x: jax.Array,             # (B, S, d) decoder states
    enc_k: jax.Array,         # (B, T, Hkv, hd) precomputed encoder K
    enc_v: jax.Array,
    enc_pos: jax.Array,       # (B, T)
    cfg: ModelConfig,
    *,
    attn_impl: str = "auto",
) -> jax.Array:
    """Encoder-decoder cross attention (no positions on q side, not causal)."""
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    q = (x @ p["wq"]).reshape(B, S, cfg.n_heads, hd)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
    q_pos = jnp.zeros((B, S), jnp.int32)
    o = attention(
        q, enc_k, enc_v, q_pos, enc_pos, causal=False,
        scale=attn_scale(cfg), impl=attn_impl,
    )
    return gqa_out(p, o)


def cross_attn_kv(p: dict, enc_out: jax.Array, cfg: ModelConfig):
    """Precompute cross-attention K/V from encoder output (at prefill)."""
    B, T, _ = enc_out.shape
    hd = cfg.resolved_head_dim
    k = (enc_out @ p["wk"]).reshape(B, T, cfg.n_kv_heads, hd)
    v = (enc_out @ p["wv"]).reshape(B, T, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    return k, v
