"""Layer-stack assembly for all architecture families.

Layers are *stacked* along a leading axis and driven by ``lax.scan``
(MaxText-style) so the lowered HLO is O(1) in depth — essential for the
512-device dry-run compiles of 60-layer models on one CPU core.

Two forward paths:

* ``forward_train`` — full-sequence self-attention, no cache, optional
  rematerialization + Megatron-style sequence-parallel residual stream
  (S sharded over the model axis between blocks).
* ``forward_cached`` — the serving path, unified for prefill (m = S) and
  decode/probe (m small).  The KV/SSM cache is a pytree carried through the
  layer scan; new K/V are scattered into caller-chosen ``slots``.  Probing
  (EAT) is just a forward_cached call whose returned cache is discarded.

Cache layout (created in serving/cache.py):
  {"layers": <per-segment stacked entries>, "pos": (B, C) int32 slot
   positions (-1 = empty), "cur": scalar int32 committed length}
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import attention as att
from repro.models import ssm as ssm_mod
from repro.models.common import mlp_apply, mlp_init, rmsnorm, rmsnorm_init
from repro.models.moe import moe_apply, moe_init
from repro.sharding.partition import ShardCtx

Params = dict
Cache = dict


def write_slots(cur, m: int, capacity: int):
    """Slot indices for the next ``m`` tokens (ring when capacity
    exceeded) — the slot convention forward_cached expects."""
    return (cur + jnp.arange(m, dtype=jnp.int32)) % capacity


# ------------------------------------------------------------ paged KV cache
#
# The block-paged cache (docs/architecture.md §Paged KV cache) keeps the
# ring cache's LOGICAL addressing — the same ``slots`` / ``pos`` / ``cur``
# convention above — but stores K/V in a pool of fixed-size physical pages:
# pool (num_pages, page_size, ...tail) plus a per-row page table (B, NB)
# mapping logical block ``slot // page_size`` -> physical page.  Entry 0 of
# the pool is a reserved trash page: unmapped blocks read and write it, and
# every read from it is position-masked (pos=-1 slots contribute exactly
# 0.0 in all attention impls), so the gathered logical view is
# element-for-element identical to the ring buffer wherever it matters.


def gather_pages(pool: jax.Array, table: jax.Array) -> jax.Array:
    """Physical pages -> the logical ring view.

    pool: (P, ps, ...tail); table: (B, NB) int32.
    Returns (B, NB*ps, ...tail) — the per-row logical cache the attention
    mask addresses by ``kv_pos`` exactly as it addresses the ring buffer.
    """
    B, NB = table.shape
    g = pool[table]                                   # (B, NB, ps, ...tail)
    return g.reshape((B, NB * pool.shape[1]) + pool.shape[2:])


def scatter_pages(pool: jax.Array, table: jax.Array, slots: jax.Array,
                  new: jax.Array) -> jax.Array:
    """Write ``new`` (B, m, ...tail) at logical ``slots`` (m,) through the
    page table.  Rows whose block is unmapped (table entry 0) land in the
    trash page — a don't-care, since their ``pos`` stays -1/masked."""
    ps = pool.shape[1]
    pages = table[:, slots // ps]                     # (B, m)
    offs = jnp.broadcast_to((slots % ps)[None, :], pages.shape)
    return pool.at[pages, offs].set(new.astype(pool.dtype))


def page_native_ok(cfg: ModelConfig, ctx: ShardCtx, m: int) -> bool:
    """True when the page-native decode attention (kernels/paged_attention)
    can serve this call: GQA entries (MLA latents keep the gather path),
    decode/probe-sized query widths, and — on a mesh — kv heads divisible
    by the model axis so the pools shard over heads (the not-divisible case
    belongs to ``seq_sharded_decode_attention``).  The SAME predicate gates
    the ring and the paged branches, so both backends always pick the same
    implementation — the per-impl paged==ring bit-exactness contract."""
    return (
        cfg.mla is None and m <= 8
        and (ctx.mesh is None or cfg.n_kv_heads % ctx.model_size == 0)
    )


# ===================================================================== init


def _stack_init(key, n: int, fn):
    return jax.vmap(fn)(jax.random.split(key, n))


def attn_block_init(key, cfg: ModelConfig, dtype, *, use_moe: bool,
                    d_ff: int, d_in: int | None = None, cross: bool = False) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d = cfg.d_model
    p: dict = {"norm1": rmsnorm_init(d_in or d, dtype, cfg.rmsnorm_one_plus)}
    p["attn"] = (
        att.mla_init(k1, cfg, dtype) if cfg.mla is not None
        else att.gqa_init(k1, cfg, dtype, d_in=d_in)
    )
    if cross:
        p["norm_c"] = rmsnorm_init(d, dtype, cfg.rmsnorm_one_plus)
        p["cross"] = att.cross_attn_init(k3, cfg, dtype)
    p["norm2"] = rmsnorm_init(d, dtype, cfg.rmsnorm_one_plus)
    if use_moe:
        p["moe"] = moe_init(k2, cfg, dtype)
    else:
        p["ffn"] = mlp_init(k2, cfg, d_ff, dtype, d_in=d)
    return p


def ssm_block_init(key, cfg: ModelConfig, dtype) -> dict:
    k1, _ = jax.random.split(key)
    return {
        "norm": rmsnorm_init(cfg.d_model, dtype, cfg.rmsnorm_one_plus),
        "ssm": ssm_mod.ssm_init(k1, cfg, dtype),
    }


def init_stack(key, cfg: ModelConfig, dtype) -> Params:
    """All non-embedding parameters, organized by scan segment."""
    ks = jax.random.split(key, 8)
    p: Params = {"final_norm": rmsnorm_init(cfg.d_model, dtype, cfg.rmsnorm_one_plus)}

    if cfg.arch_type in ("dense", "vlm"):
        p["layers"] = _stack_init(
            ks[0], cfg.n_layers,
            lambda k: attn_block_init(k, cfg, dtype, use_moe=False, d_ff=cfg.d_ff),
        )
    elif cfg.arch_type == "moe":
        fk = cfg.moe.first_k_dense
        dense_ff = cfg.moe.dense_d_ff or cfg.d_ff
        if fk:
            p["dense_layers"] = _stack_init(
                ks[0], fk,
                lambda k: attn_block_init(k, cfg, dtype, use_moe=False, d_ff=dense_ff),
            )
        p["moe_layers"] = _stack_init(
            ks[1], cfg.n_layers - fk,
            lambda k: attn_block_init(k, cfg, dtype, use_moe=True, d_ff=cfg.d_ff),
        )
    elif cfg.arch_type == "ssm":
        p["layers"] = _stack_init(ks[0], cfg.n_layers, lambda k: ssm_block_init(k, cfg, dtype))
    elif cfg.arch_type == "hybrid":
        kinds = cfg.block_kinds()
        pat = cfg.hybrid_pattern
        n_ssm_per = sum(1 for k in pat if k == "ssm")
        n_groups = len(kinds) // len(pat)
        p["groups"] = _stack_init(
            ks[0], n_groups,
            lambda k: _stack_init(k, n_ssm_per, lambda kk: ssm_block_init(kk, cfg, dtype)),
        )
        p["shared_attn"] = attn_block_init(
            ks[1], cfg, dtype, use_moe=False, d_ff=cfg.d_ff, d_in=2 * cfg.d_model
        )
    elif cfg.arch_type == "encdec":
        p["enc_layers"] = _stack_init(
            ks[0], cfg.n_encoder_layers,
            lambda k: attn_block_init(k, cfg, dtype, use_moe=False, d_ff=cfg.d_ff),
        )
        p["enc_norm"] = rmsnorm_init(cfg.d_model, dtype, cfg.rmsnorm_one_plus)
        p["dec_layers"] = _stack_init(
            ks[1], cfg.n_layers,
            lambda k: attn_block_init(k, cfg, dtype, use_moe=False, d_ff=cfg.d_ff, cross=True),
        )
    else:
        raise ValueError(cfg.arch_type)
    return p


# ===================================================================== blocks


def _res_constraint(x, ctx: ShardCtx, seq_parallel: bool):
    if ctx.mesh is None:
        return x
    b = ctx.batch_spec_entry()
    return ctx.wsc(x, P(b, ctx.model_axis, None) if seq_parallel else P(b, None, None))


def _heads_constraint(q, cfg, ctx: ShardCtx):
    if ctx.mesh is None:
        return q
    n_h = q.shape[2]
    ax = ctx.model_axis if n_h % ctx.model_size == 0 else None
    return ctx.wsc(q, P(ctx.batch_spec_entry(), None, ax, None))


def attn_block_full(
    p: dict, x, positions, pos1d, cfg: ModelConfig, ctx: ShardCtx, *,
    use_moe: bool, causal: bool = True, window: int = 0, attn_impl: str = "auto",
    seq_parallel: bool = False, enc_kv=None, enc_pos=None, x_extra=None,
):
    """Full-sequence block (train / encoder).  Returns (x, aux)."""
    h_in = x if x_extra is None else jnp.concatenate([x, x_extra], axis=-1)
    if seq_parallel:
        # §Perf P3': force the sequence-parallel all-gather HERE — on the
        # bf16 d_model-wide RESIDUAL — not after the q/k projections (GSPMD
        # otherwise gathers 128 heads x 192 dims for MLA, in f32: ~20x the
        # bytes).  Gathering before the norm keeps the moved tensor bf16
        # (the norm's f32 intermediates stay local; its recompute over the
        # model axis is elementwise — negligible).
        h_in = _res_constraint(h_in, ctx, False)
    h = rmsnorm(h_in, p["norm1"], cfg.norm_eps, cfg.rmsnorm_one_plus)
    if cfg.mla is not None:
        y, _ = att.mla_self_attention(
            p["attn"], h, positions, pos1d, cfg, window=window, attn_impl=attn_impl
        )
    else:
        y, _ = att.gqa_self_attention(
            p["attn"], h, positions, pos1d, cfg, causal=causal, window=window,
            attn_impl=attn_impl,
        )
    x = _res_constraint(x + y, ctx, seq_parallel)

    if enc_kv is not None:
        hc = rmsnorm(x, p["norm_c"], cfg.norm_eps, cfg.rmsnorm_one_plus)
        ck, cv = att.cross_attn_kv(p["cross"], enc_kv, cfg)
        x = x + att.cross_attention(p["cross"], hc, ck, cv, enc_pos, cfg, attn_impl=attn_impl)

    x_full = _res_constraint(x, ctx, False) if seq_parallel else x
    h2 = rmsnorm(x_full, p["norm2"], cfg.norm_eps, cfg.rmsnorm_one_plus)
    if use_moe:
        f, aux = moe_apply(p["moe"], h2, cfg, ctx)
    else:
        f, aux = mlp_apply(p["ffn"], h2, cfg), jnp.zeros((), jnp.float32)
    x = _res_constraint(x + f, ctx, seq_parallel)
    return x, aux


def attn_block_cached(
    p: dict, x, positions, pos1d, cfg: ModelConfig, ctx: ShardCtx,
    entry: dict, kv_pos, slots, *,
    use_moe: bool, window: int = 0, attn_impl: str = "auto",
    cross_cache: tuple | None = None, enc_pos=None, x_extra=None,
    paged: tuple | None = None, paged_impl: str = "gather",
    page_block: int = 16,
):
    """Cached block (prefill m=S / decode m small).  Returns (x, entry, aux).

    ``entry`` holds this layer's cache arrays; new K/V are scattered into
    ``slots`` (B-shared (m,) int32) before the attention read.  With
    ``paged=(page_table, page_size, blocks)`` the entry arrays are page
    POOLS ((P, ps, ...) instead of (B, C, ...)): new K/V scatter through
    the page table, and the attention read depends on ``paged_impl``:

    * ``"gather"`` (default): materialize the gathered logical view — same
      mask, same ``kv_pos``, bit-identical to the ring (docs/architecture.md);
    * ``"auto"/"xla"/"pallas"`` with ``blocks`` present (the engine's
      compacted mapped-page list): read K/V straight off the pools through
      the page list — O(mapped pages) per token, no logical-view
      materialization.  The ring branch routes through the SAME
      block-sequential algorithm (``ring_decode_attention``) so the two
      backends stay bit-identical per impl (kernels/paged_attention/ref.py).
    """
    h_in = x if x_extra is None else jnp.concatenate([x, x_extra], axis=-1)
    h = rmsnorm(h_in, p["norm1"], cfg.norm_eps, cfg.rmsnorm_one_plus)
    if cfg.mla is not None:
        q_nope, q_rope = att.mla_q(p["attn"], h, positions, cfg)
        c_new, kr_new = att.mla_latent(p["attn"], h, positions, cfg)
        entry = dict(entry)
        if paged is not None:
            table = paged[0]
            entry["c"] = scatter_pages(entry["c"], table, slots, c_new)
            entry["kr"] = scatter_pages(entry["kr"], table, slots, kr_new)
            cache_c = gather_pages(entry["c"], table)
            cache_kr = gather_pages(entry["kr"], table)
        else:
            entry["c"] = entry["c"].at[:, slots].set(c_new.astype(entry["c"].dtype))
            entry["kr"] = entry["kr"].at[:, slots].set(kr_new.astype(entry["kr"].dtype))
            cache_c, cache_kr = entry["c"], entry["kr"]
        y = att.mla_absorbed_attend(
            p["attn"], q_nope, q_rope, pos1d, cfg, cache_c, cache_kr, kv_pos,
            window=window, attn_impl=attn_impl, ctx=ctx,
        )
    else:
        q, k_new, v_new = att.gqa_qkv(p["attn"], h, positions, cfg)
        q = _heads_constraint(q, cfg, ctx)
        native = paged_impl != "gather" and page_native_ok(cfg, ctx, x.shape[1])
        entry = dict(entry)
        o = None
        if paged is not None:
            table, ps, blocks = paged
            entry["k"] = scatter_pages(entry["k"], table, slots, k_new)
            entry["v"] = scatter_pages(entry["v"], table, slots, v_new)
            if native and blocks is None:
                # a silent gather fallback here would split the per-impl
                # paged==ring pairing (the ring side WOULD run the block
                # scan) — fail at trace time instead; paged caches for the
                # native impls come from cache.alloc_paged_template
                raise ValueError(
                    f"paged_impl={paged_impl!r} needs the compacted page "
                    f"list: allocate the cache with "
                    f"serving.cache.alloc_paged_template(..., native=True) "
                    f"(or alloc_paged_cache(block_bucket=...))")
            if native:
                # page-native read: pools + compacted page list, no
                # gathered logical view (O(mapped pages) per token)
                from repro.kernels.paged_attention import ops as paged_ops

                bpos = paged_ops.block_positions(
                    kv_pos, blocks["pages"], blocks["logical"], ps)
                o = paged_ops.paged_decode_attention(
                    q, entry["k"], entry["v"], blocks["pages"],
                    blocks["count"], bpos, pos1d, window=window,
                    scale=att.attn_scale(cfg), impl=paged_impl,
                )
            else:
                k_view = gather_pages(entry["k"], table)
                v_view = gather_pages(entry["v"], table)
        else:
            ps = page_block
            entry["k"] = entry["k"].at[:, slots].set(k_new.astype(entry["k"].dtype))
            entry["v"] = entry["v"].at[:, slots].set(v_new.astype(entry["v"].dtype))
            k_view, v_view = entry["k"], entry["v"]
        if o is not None:
            pass
        elif att.use_seq_sharded_cache(cfg, ctx, x.shape[1]):
            # §Perf P1': partial-softmax decode over the seq-sharded cache
            # (avoids GSPMD all-gathering the cache every attention read)
            o = att.seq_sharded_decode_attention(
                q, k_view, v_view, pos1d, kv_pos, ctx,
                window=window, scale=att.attn_scale(cfg),
            )
        elif native and paged is None:
            # the ring comparator of the page-native path: the SAME
            # block-sequential accumulation over the dense cache (all
            # blocks visited in logical order — ref.py's identity-step
            # argument makes the paged path bit-identical to this one)
            from repro.kernels.paged_attention import ops as paged_ops

            o = paged_ops.ring_decode_attention(
                q, k_view, v_view, pos1d, kv_pos, page_size=ps,
                window=window, scale=att.attn_scale(cfg), impl=paged_impl,
            )
        else:
            o = att.attention(
                q, k_view, v_view, pos1d, kv_pos, causal=True, window=window,
                scale=att.attn_scale(cfg), impl=attn_impl,
            )
        y = att.gqa_out(p["attn"], o)
    x = _res_constraint(x + y, ctx, False)

    if cross_cache is not None:
        hc = rmsnorm(x, p["norm_c"], cfg.norm_eps, cfg.rmsnorm_one_plus)
        ck, cv = cross_cache
        x = x + att.cross_attention(p["cross"], hc, ck, cv, enc_pos, cfg, attn_impl=attn_impl)

    h2 = rmsnorm(x, p["norm2"], cfg.norm_eps, cfg.rmsnorm_one_plus)
    if use_moe:
        f, aux = moe_apply(p["moe"], h2, cfg, ctx)
    else:
        f, aux = mlp_apply(p["ffn"], h2, cfg), jnp.zeros((), jnp.float32)
    x = _res_constraint(x + f, ctx, False)
    return x, entry, aux


def ssm_block_full(p: dict, x, cfg: ModelConfig, ctx: ShardCtx, *,
                   valid=None, state=None, seq_parallel: bool = False):
    h = rmsnorm(x, p["norm"], cfg.norm_eps, cfg.rmsnorm_one_plus)
    y, new_state = ssm_mod.ssm_forward(
        p["ssm"], h, cfg, valid=valid,
        conv_tail=None if state is None else state["conv"],
        h0=None if state is None else state["ssm"],
    )
    # NOTE: SSD's chunk recurrence couples the sequence dim — no seq-parallel
    # residual stream for SSM blocks (the scan must see contiguous chunks).
    x = _res_constraint(x + y, ctx, False)
    return x, new_state


def ssm_block_step(p: dict, x, cfg: ModelConfig, ctx: ShardCtx, state, *, valid=None):
    h = rmsnorm(x, p["norm"], cfg.norm_eps, cfg.rmsnorm_one_plus)
    y, new_state = ssm_mod.ssm_step(p["ssm"], h, cfg, state, valid=valid)
    x = _res_constraint(x + y, ctx, False)
    return x, new_state


# ===================================================================== stacks


def _scan(body, carry, xs, *, remat: bool, length=None, unroll: bool = False):
    """lax.scan over stacked layers, or a python loop when ``unroll``.

    Unrolling exists for the dry-run *cost probes*: XLA's cost_analysis
    counts a while-loop body once, so the roofline extracts per-layer costs
    from two small unrolled depths and extrapolates (EXPERIMENTS.md §Dry-run
    methodology).  Production lowering always uses the scan.
    """
    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    if not unroll:
        return lax.scan(body, carry, xs, length=length)
    n = length if length is not None else jax.tree_util.tree_leaves(xs)[0].shape[0]
    ys_list = []
    for i in range(n):
        xi = None if xs is None else jax.tree_util.tree_map(lambda x: x[i], xs)
        carry, y = body(carry, xi)
        ys_list.append(y)
    if ys_list and ys_list[0] is not None:
        ys = jax.tree_util.tree_map(lambda *zs: jnp.stack(zs), *ys_list)
    else:
        ys = None
    return carry, ys


def forward_train(
    params: Params, x, positions, pos1d, cfg: ModelConfig, ctx: ShardCtx, *,
    valid=None, enc_out=None, enc_pos=None, attn_impl: str = "auto",
    remat: bool = True, window: int = 0, unroll: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Full-sequence forward over the stack.  Returns (hidden, aux_loss)."""
    seq_par = ctx.mesh is not None and cfg.arch_type not in ("ssm", "hybrid")
    x = _res_constraint(x, ctx, seq_par)
    aux_total = jnp.zeros((), jnp.float32)

    if cfg.arch_type in ("dense", "vlm"):
        def body(carry, p_layer):
            xx, aux = carry
            xx, a = attn_block_full(
                p_layer, xx, positions, pos1d, cfg, ctx, use_moe=False,
                window=window, attn_impl=attn_impl, seq_parallel=seq_par,
            )
            return (xx, aux + a), None

        (x, aux_total), _ = _scan(body, (x, aux_total), params["layers"], remat=remat, unroll=unroll)

    elif cfg.arch_type == "moe":
        if "dense_layers" in params:
            def body_d(carry, p_layer):
                xx, aux = carry
                xx, a = attn_block_full(
                    p_layer, xx, positions, pos1d, cfg, ctx, use_moe=False,
                    window=window, attn_impl=attn_impl, seq_parallel=seq_par,
                )
                return (xx, aux + a), None

            (x, aux_total), _ = _scan(body_d, (x, aux_total), params["dense_layers"], remat=remat, unroll=unroll)

        def body_m(carry, p_layer):
            xx, aux = carry
            xx, a = attn_block_full(
                p_layer, xx, positions, pos1d, cfg, ctx, use_moe=True,
                window=window, attn_impl=attn_impl, seq_parallel=seq_par,
            )
            return (xx, aux + a), None

        (x, aux_total), _ = _scan(body_m, (x, aux_total), params["moe_layers"], remat=remat, unroll=unroll)

    elif cfg.arch_type == "ssm":
        def body_s(xx, p_layer):
            xx, _ = ssm_block_full(p_layer, xx, cfg, ctx, valid=valid)
            return xx, None

        x, _ = _scan(body_s, x, params["layers"], remat=remat, unroll=unroll)

    elif cfg.arch_type == "hybrid":
        emb0 = x

        def body_g(xx, p_group):
            def body_s(xxx, p_layer):
                xxx, _ = ssm_block_full(p_layer, xxx, cfg, ctx, valid=valid)
                return xxx, None

            xx, _ = _scan(body_s, xx, p_group, remat=False, unroll=unroll)
            xx, _ = attn_block_full(
                params["shared_attn"], xx, positions, pos1d, cfg, ctx,
                use_moe=False, window=window, attn_impl=attn_impl, x_extra=emb0,
            )
            return xx, None

        x, _ = _scan(body_g, x, params["groups"], remat=remat, unroll=unroll)

    elif cfg.arch_type == "encdec":
        assert enc_out is not None

        def body_dec(carry, p_layer):
            xx, aux = carry
            xx, a = attn_block_full(
                p_layer, xx, positions, pos1d, cfg, ctx, use_moe=False,
                window=window, attn_impl=attn_impl, seq_parallel=seq_par,
                enc_kv=enc_out, enc_pos=enc_pos,
            )
            return (xx, aux + a), None

        (x, aux_total), _ = _scan(body_dec, (x, aux_total), params["dec_layers"], remat=remat, unroll=unroll)

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps, cfg.rmsnorm_one_plus)
    return x, aux_total


def encode(params: Params, frames, enc_pos, cfg: ModelConfig, ctx: ShardCtx, *,
           attn_impl: str = "auto", remat: bool = False, unroll: bool = False) -> jax.Array:
    """Bidirectional encoder over stub frontend frames (B, T, d)."""
    x = frames

    def body(xx, p_layer):
        xx, _ = attn_block_full(
            p_layer, xx, enc_pos, enc_pos, cfg, ctx, use_moe=False,
            causal=False, attn_impl=attn_impl,
        )
        return xx, None

    x, _ = _scan(body, x, params["enc_layers"], remat=remat, unroll=unroll)
    return rmsnorm(x, params["enc_norm"], cfg.norm_eps, cfg.rmsnorm_one_plus)


def forward_cached(
    params: Params, x, positions, pos1d, slots, cache: Cache,
    cfg: ModelConfig, ctx: ShardCtx, *,
    attn_impl: str = "auto", window: int = 0, unroll: bool = False,
    paged_impl: str = "gather", page_block: int = 16,
) -> tuple[jax.Array, Cache, jax.Array]:
    """Unified prefill (m=S) / decode / probe forward against a cache.

    Returns (hidden (B,m,d), new_cache, aux).  Committing vs probing is the
    caller's choice of whether to keep ``new_cache``.
    """
    B, m, _ = x.shape
    kv_pos = cache["pos"].at[:, slots].set(pos1d)
    new_cache = dict(cache)
    new_cache["pos"] = kv_pos
    new_cache["cur"] = cache["cur"] + m
    aux_total = jnp.zeros((), jnp.float32)
    x = _res_constraint(x, ctx, False)
    layers = cache.get("layers", {})
    # block-paged cache: thread (page_table, page_size, blocks) into the
    # attention blocks — logical addressing (slots/pos/cur) is unchanged;
    # ``blocks`` (the engine's compacted mapped-page list) enables the
    # page-native read when ``paged_impl`` asks for it
    paged = None
    if "page_table" in cache:
        table = cache["page_table"]
        paged = (table, cache["pos"].shape[1] // table.shape[1],
                 cache.get("blocks"))

    if cfg.arch_type in ("dense", "vlm", "moe", "encdec"):
        segs = []
        if cfg.arch_type == "moe":
            if "dense_layers" in params:
                segs.append(("dense_seg", params["dense_layers"], False))
            segs.append(("moe_seg", params["moe_layers"], True))
        elif cfg.arch_type == "encdec":
            segs.append(("dec_seg", params["dec_layers"], False))
        else:
            segs.append(("seg", params["layers"], False))

        new_layers = dict(layers)
        for seg_name, seg_params, use_moe in segs:
            seg_cache = layers[seg_name]
            cross = cfg.arch_type == "encdec"

            def body(carry, xs):
                xx, aux = carry
                p_layer, entry = xs
                cc = (entry["ck"], entry["cv"]) if cross else None
                xx, entry_new, a = attn_block_cached(
                    p_layer, xx, positions, pos1d, cfg, ctx, entry, kv_pos, slots,
                    use_moe=use_moe, window=window, attn_impl=attn_impl,
                    cross_cache=cc, enc_pos=cache.get("enc_pos"), paged=paged,
                    paged_impl=paged_impl, page_block=page_block,
                )
                if cross:  # cross kv is static; don't re-emit to save copies
                    entry_new["ck"], entry_new["cv"] = entry["ck"], entry["cv"]
                return (xx, aux + a), entry_new

            (x, aux_total), seg_new = _scan(body, (x, aux_total), (seg_params, seg_cache), remat=False, unroll=unroll)
            new_layers[seg_name] = seg_new
        new_cache["layers"] = new_layers

    elif cfg.arch_type == "ssm":
        # prefill (large m) uses the chunked SSD path; decode steps recur
        use_full = m > 16
        valid = pos1d >= 0

        def body_s(xx, xs):
            p_layer, st = xs
            if use_full:
                xx, st_new = ssm_block_full(p_layer, xx, cfg, ctx, valid=valid, state=st)
            else:
                xx, st_new = ssm_block_step(p_layer, xx, cfg, ctx, st)
            return xx, st_new

        x, st_all = _scan(body_s, x, (params["layers"], layers["seg"]), remat=False, unroll=unroll)
        new_cache["layers"] = {"seg": st_all}

    elif cfg.arch_type == "hybrid":
        emb0 = x
        seg_cache = layers["ssm_seg"]      # pytree stacked (G, n_ssm_per, ...)
        attn_cache = layers["attn_seg"]    # entries stacked (G, ...)
        use_full = m > 16
        valid = pos1d >= 0

        def body_g(carry, xs):
            xx, aux = carry
            p_group, st_group, attn_entry = xs

            def body_s(xxx, xs_inner):
                p_layer, st = xs_inner
                if use_full:
                    xxx, st_new = ssm_block_full(p_layer, xxx, cfg, ctx, valid=valid, state=st)
                else:
                    xxx, st_new = ssm_block_step(p_layer, xxx, cfg, ctx, st)
                return xxx, st_new

            xx, st_group_new = _scan(body_s, xx, (p_group, st_group), remat=False, unroll=unroll)
            xx, attn_entry_new, a = attn_block_cached(
                params["shared_attn"], xx, positions, pos1d, cfg, ctx,
                attn_entry, kv_pos, slots, use_moe=False, window=window,
                attn_impl=attn_impl, x_extra=emb0, paged=paged,
                paged_impl=paged_impl, page_block=page_block,
            )
            return (xx, aux + a), (st_group_new, attn_entry_new)

        (x, aux_total), (st_new, attn_new) = _scan(
            body_g, (x, aux_total), (params["groups"], seg_cache, attn_cache),
            remat=False, unroll=unroll,
        )
        new_cache["layers"] = {"ssm_seg": st_new, "attn_seg": attn_new}

    else:
        raise ValueError(cfg.arch_type)

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps, cfg.rmsnorm_one_plus)
    return x, new_cache, aux_total
