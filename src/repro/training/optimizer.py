"""AdamW + cosine schedule + global-norm clipping, in pure JAX.

Optimizer state mirrors the parameter pytree (m, v in float32 regardless of
parameter dtype — the usual mixed-precision layout), so FSDP sharding rules
apply leaf-wise to the moments exactly as to the parameters.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


class OptState(NamedTuple):
    step: jax.Array
    m: dict
    v: dict


def cosine_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def adamw_init(params) -> OptState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OptState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree_util.tree_map(zeros, params),
        v=jax.tree_util.tree_map(zeros, params),
    )


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree_util.tree_leaves(tree))
    )


def adamw_update(cfg: AdamWConfig, grads, opt: OptState, params):
    """Returns (new_params, new_opt, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    step = opt.step + 1
    lr = cosine_schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh, vh = m / b1c, v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(opt.m)
    flat_v = jax.tree_util.tree_leaves(opt.v)
    new_p, new_m, new_v = [], [], []
    for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
        a, b, c = upd(p, g, m, v)
        new_p.append(a)
        new_m.append(b)
        new_v.append(c)
    unflat = lambda leaves: jax.tree_util.tree_unflatten(treedef, leaves)
    return (
        unflat(new_p),
        OptState(step=step, m=unflat(new_m), v=unflat(new_v)),
        {"grad_norm": gnorm, "lr": lr},
    )
