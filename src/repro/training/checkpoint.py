"""Flat-dict msgpack checkpointing (host-local; restores onto any mesh by
re-sharding at load)."""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

from repro.utils.treeutil import tree_flatten_with_paths


def save_checkpoint(path: str, tree) -> None:
    flat = tree_flatten_with_paths(tree)
    payload = {}
    for key, leaf in flat:
        arr = np.asarray(jax.device_get(leaf))
        payload[key] = {
            "dtype": str(arr.dtype),
            "shape": list(arr.shape),
            "data": arr.tobytes(),
        }
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(path, "wb") as f:
        f.write(msgpack.packb(payload, use_bin_type=True))


def load_checkpoint(path: str, like):
    """Restore into the structure of ``like`` (shapes/dtypes must match)."""
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=False)
    flat = tree_flatten_with_paths(like)
    leaves = []
    for key, leaf in flat:
        rec = payload[key]
        arr = np.frombuffer(rec["data"], dtype=np.dtype(rec["dtype"])).reshape(rec["shape"])
        assert tuple(arr.shape) == tuple(leaf.shape), (key, arr.shape, leaf.shape)
        leaves.append(jnp.asarray(arr, dtype=leaf.dtype))
    treedef = jax.tree_util.tree_structure(like)
    return jax.tree_util.tree_unflatten(treedef, leaves)
