from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update, cosine_schedule  # noqa: F401
from repro.training.train_loop import TrainConfig, make_train_step  # noqa: F401
