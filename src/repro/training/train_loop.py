"""pjit train step factory.

``make_train_step(model, opt_cfg)`` returns (train_step, init_state):
train_step is jit-compiled with parameter/optimizer shardings from
``sharding.partition`` and batch sharding over (pod, data); suitable both
for real training (tiny models on CPU) and for ``.lower().compile()``
dry-runs on the production mesh.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.model import Model
from repro.sharding.partition import param_pspecs
from repro.training.optimizer import AdamWConfig, OptState, adamw_init, adamw_update


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: AdamWConfig = AdamWConfig()
    remat: bool = True
    z_loss: float = 1e-4


class TrainState(NamedTuple):
    params: dict
    opt: OptState


def batch_pspecs(model: Model, batch: dict):
    """Batch arrays shard over (pod, data) on their leading axis."""
    ctx = model.ctx
    if ctx.mesh is None:
        return jax.tree_util.tree_map(lambda _: P(), batch)
    b = ctx.batch_spec_entry()
    return jax.tree_util.tree_map(lambda x: P(b, *([None] * (x.ndim - 1))), batch)


def state_pspecs(model: Model, state: TrainState):
    specs = param_pspecs(state.params, model.cfg, model.ctx)
    return TrainState(
        params=specs,
        opt=OptState(step=P(), m=specs, v=specs),
    )


def make_train_step(model: Model, tcfg: TrainConfig = TrainConfig()):
    """Returns ``train_step(state, batch) -> (state, metrics)`` (pure fn,
    un-jitted — callers jit with the shardings they want)."""

    def loss_fn(params, batch):
        loss, metrics = model.train_loss(
            params, batch, remat=tcfg.remat, z_loss=tcfg.z_loss
        )
        return loss, metrics

    def train_step(state: TrainState, batch: dict):
        (_, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            state.params, batch
        )
        params, opt, opt_metrics = adamw_update(tcfg.opt, grads, state.opt, state.params)
        metrics.update(opt_metrics)
        return TrainState(params=params, opt=opt), metrics

    return train_step


def jit_train_step(model: Model, tcfg: TrainConfig, state: TrainState, batch: dict):
    """Jit with explicit in/out shardings on the production mesh (or plain
    jit when ctx.mesh is None)."""
    step = make_train_step(model, tcfg)
    ctx = model.ctx
    if ctx.mesh is None:
        return jax.jit(step, donate_argnums=0)
    sspec = state_pspecs(model, state)
    bspec = batch_pspecs(model, batch)
    to_sharding = lambda tree: jax.tree_util.tree_map(
        lambda s: NamedSharding(ctx.mesh, s), tree
    )
    return jax.jit(
        step,
        in_shardings=(to_sharding(sspec), to_sharding(bspec)),
        out_shardings=(to_sharding(sspec), None),
        donate_argnums=0,
    )


def init_train_state(model: Model, key) -> TrainState:
    params = model.init(key)
    return TrainState(params=params, opt=adamw_init(params))
