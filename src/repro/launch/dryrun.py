import os
if __name__ == "__main__":
    # only when executed as a script: the analysis passes (tools/audit)
    # import this module for its lowering helpers and must not have their
    # process's device topology rewritten underneath them
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes and extract the roofline terms.

MUST be run as its own process (``python -m repro.launch.dryrun``): the
lines above run before any other import so the 512 placeholder host devices
exist before jax initializes (``runpy`` executes the module body with
``__name__ == "__main__"``, so the guard still fires ahead of the jax
import below).

Per (arch, shape, mesh):
  * train_4k     -> full train_step (fwd+bwd+AdamW) with FSDP+TP shardings
  * prefill_32k  -> Model.prefill
  * decode shapes-> serve_step (decode + EAT probe + EMA + exit decision)
compiled artifacts yield memory_analysis (fits-in-HBM proof),
cost_analysis (FLOPs / bytes), and the collective traffic parsed from the
post-SPMD HLO — everything EXPERIMENTS.md §Dry-run/§Roofline reads.

Usage:
  python -m repro.launch.dryrun --arch gemma-2b --shape train_4k
  python -m repro.launch.dryrun --all [--multipod] --out artifacts/dryrun
"""
import argparse      # noqa: E402
import json          # noqa: E402
import re            # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp                         # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P   # noqa: E402

from repro.configs import ASSIGNED_ARCHS        # noqa: E402
from repro.configs.base import INPUT_SHAPES, get_config      # noqa: E402
from repro.launch import input_specs as ispec   # noqa: E402
from repro.launch.mesh import make_ctx          # noqa: E402
from repro.models.model import Model            # noqa: E402
from repro.serving.cache import cache_pspecs    # noqa: E402
from repro.serving.executor import (            # noqa: E402
    ServeStepConfig,
    build_serve_step_program,
)
from repro.utils.jax_compat import cost_analysis_dict        # noqa: E402
from repro.sharding.partition import param_pspecs            # noqa: E402
from repro.training.optimizer import OptState   # noqa: E402
from repro.training.train_loop import (         # noqa: E402
    TrainConfig,
    TrainState,
    batch_pspecs,
    make_train_step,
    state_pspecs,
)

COLLECTIVE_OPS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"\b([a-z]+[0-9]+|pred)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def parse_collective_bytes(hlo_text: str) -> dict:
    """Sum operand bytes of every collective op in the (per-partition)
    post-SPMD HLO.  Returns {opcode: bytes, 'total': bytes, 'count': n}."""
    out = {op: 0 for op in COLLECTIVE_OPS}
    count = 0
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"^[%\w.\-]+\s*=\s*(.+)$", ls)
        if not m:
            continue
        rhs = m.group(1)
        opm = re.search(r"\b(" + "|".join(COLLECTIVE_OPS) + r")(?:-start|-done)?\(", rhs)
        if not opm:
            continue
        op = opm.group(1)
        if "-done(" in rhs:       # avoid double counting start/done pairs
            continue
        shapes = _SHAPE_RE.findall(rhs)
        if not shapes:
            continue
        # first shape(s) before the opcode are the result; shapes after the
        # '(' are operands.  Split at the opcode position.
        op_idx = rhs.index(opm.group(0))
        operand_str = rhs[op_idx:]
        operands = _SHAPE_RE.findall(operand_str)
        use = operands if operands else shapes[:1]
        out[op] += sum(_shape_bytes(d, s) for d, s in use)
        count += 1
    out["total"] = sum(out[o] for o in COLLECTIVE_OPS)
    out["count"] = count
    return out


def _shardings(ctx, tree_specs):
    return jax.tree_util.tree_map(lambda s: NamedSharding(ctx.mesh, s), tree_specs)


def probe_depths(cfg) -> tuple[int, int]:
    """Two small depths for the unrolled cost probes (see run_one)."""
    if cfg.arch_type == "hybrid":
        g = len(cfg.hybrid_pattern)
        return g, 2 * g
    if cfg.moe is not None:
        fk = cfg.moe.first_k_dense
        return fk + 2, fk + 4
    return 2, 4


def override_depth(cfg, n_layers: int):
    import dataclasses as dc

    kw: dict = {"n_layers": n_layers}
    if cfg.arch_type == "encdec":
        kw["n_encoder_layers"] = n_layers
    return dc.replace(cfg, **kw)


def build_lowerable(arch: str, shape_name: str, multi_pod: bool,
                    cfg_override=None, unroll: bool = False,
                    variant: dict | None = None):
    """Returns (lower_fn, descr) — lower_fn() -> jax.stages.Lowered.

    ``variant`` (§Perf hillclimb knobs): {"fsdp": bool,
    "moe_combine": "psum_f32|psum_bf16|scatter", "fused_probe": bool}.
    """
    import dataclasses as dc

    variant = variant or {}
    cfg = cfg_override if cfg_override is not None else get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    ctx = make_ctx(multi_pod=multi_pod)
    ctx = dc.replace(
        ctx,
        fsdp=variant.get("fsdp", True),
        moe_combine=variant.get("moe_combine", "psum_f32"),
    )
    model = Model(cfg, ctx, attn_impl="xla", unroll=unroll)
    b = ctx.batch_spec_entry() if shape.global_batch % ctx.data_size == 0 else None
    window = ispec.runtime_window(cfg, shape)

    params_struct = ispec.params_specs(model)
    pspecs = param_pspecs(params_struct, cfg, ctx)
    psh = _shardings(ctx, pspecs)

    if shape.kind == "train":
        batch = ispec.train_batch_specs(cfg, shape)
        state_struct = TrainState(
            params=params_struct,
            opt=OptState(
                step=jax.ShapeDtypeStruct((), jnp.int32),
                m=jax.tree_util.tree_map(
                    lambda x: jax.ShapeDtypeStruct(x.shape, jnp.float32), params_struct
                ),
                v=jax.tree_util.tree_map(
                    lambda x: jax.ShapeDtypeStruct(x.shape, jnp.float32), params_struct
                ),
            ),
        )
        sspec = state_pspecs(model, state_struct)
        bspec = batch_pspecs(model, batch)
        step = make_train_step(model, TrainConfig())
        jitted = jax.jit(
            step,
            in_shardings=(_shardings(ctx, sspec), _shardings(ctx, bspec)),
            out_shardings=(_shardings(ctx, sspec), None),
            donate_argnums=0,
        )
        return (lambda: jitted.lower(state_struct, batch)), "train_step"

    if shape.kind == "prefill":
        spec = ispec.prefill_specs(cfg, shape)
        cache_struct = spec["cache"]
        cspec = cache_pspecs(cfg, ctx, cache_struct)

        has_frames = "frames" in spec
        has_img = "image_embeds" in spec

        def prefill_fn(params, tokens, positions, pos1d, cache, *extras):
            frames = extras[0] if has_frames else None
            image_embeds = extras[0] if (has_img and not has_frames) else None
            return model.prefill(
                params, tokens, positions, pos1d, cache,
                frames=frames, image_embeds=image_embeds, window=window,
            )

        in_sh = [
            psh,
            NamedSharding(ctx.mesh, P(b, None)),
            NamedSharding(ctx.mesh, P(b, None, None) if cfg.mrope_sections else P(b, None)),
            NamedSharding(ctx.mesh, P(b, None)),
            _shardings(ctx, cspec),
        ]
        args = [params_struct, spec["tokens"], spec["positions"], spec["pos1d"],
                cache_struct]
        if has_frames:
            in_sh.append(NamedSharding(ctx.mesh, P(b, None, None)))
            args.append(spec["frames"])
        if has_img:
            in_sh.append(NamedSharding(ctx.mesh, P(b, None, None)))
            args.append(spec["image_embeds"])
        jitted = jax.jit(prefill_fn, in_shardings=tuple(in_sh), donate_argnums=4)
        return (lambda: jitted.lower(*args)), "prefill"

    # decode: lower the EXECUTOR's serve-step program — the same definition
    # the engine's device-resident chunks scan, so what the roofline costs
    # out is what serving dispatches (shardings + cache donation included)
    spec = ispec.decode_specs(cfg, shape)
    cache_struct = spec["cache"]
    scfg = ServeStepConfig(window=window,
                           fused_probe=variant.get("fused_probe", False))
    jitted, mon_struct = build_serve_step_program(
        model, scfg, cache_struct, params_struct
    )
    return (
        lambda: jitted.lower(
            params_struct, cache_struct, spec["token"], spec["pos1d"],
            mon_struct, spec["rng"],
        ),
        "serve_step",
    )


def run_one(arch: str, shape_name: str, multi_pod: bool, out_dir: str | None,
            keep_hlo: bool = False, variant: dict | None = None,
            tag: str = "") -> dict:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec: dict = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "kind": shape.kind, "variant": variant or {}, "tag": tag,
    }
    reason = ispec.skip_reason(cfg, shape)
    if reason:
        rec["status"] = "skipped"
        rec["reason"] = reason
        return rec
    t0 = time.time()
    try:
        lower_fn, step_name = build_lowerable(arch, shape_name, multi_pod,
                                              variant=variant)
        lowered = lower_fn()
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = cost_analysis_dict(compiled)
        hlo = compiled.as_text()
        coll = parse_collective_bytes(hlo)
        # same sync-point screen the serving programs get (tools/audit):
        # a host callback in the costed program would invalidate the
        # roofline numbers the dry-run exists to produce
        from repro.analysis.lowered import scan_hlo_text

        rec["sync_points"] = [str(v) for v in scan_hlo_text(
            hlo, f"{arch}/{shape_name}")]

        # ---- unrolled cost probes (XLA counts scan bodies once; extract
        # per-layer costs from two small unrolled depths and extrapolate
        # linearly to the full depth — EXPERIMENTS.md §Dry-run methodology)
        L1, L2 = probe_depths(cfg)
        probes = {}
        for L in (L1, L2):
            lf, _ = build_lowerable(
                arch, shape_name, multi_pod,
                cfg_override=override_depth(cfg, L), unroll=True,
                variant=variant,
            )
            cp = lf().compile()
            pc = cost_analysis_dict(cp)
            probes[L] = {
                "flops": float(pc.get("flops", 0.0)),
                "bytes": float(pc.get("bytes accessed", 0.0)),
                "coll": parse_collective_bytes(cp.as_text()),
            }
        Lf = cfg.n_layers

        def extrap(f1: float, f2: float) -> float:
            slope = (f2 - f1) / (L2 - L1)
            return f1 + slope * (Lf - L1)

        flops_x = extrap(probes[L1]["flops"], probes[L2]["flops"])
        bytes_x = extrap(probes[L1]["bytes"], probes[L2]["bytes"])
        coll_x = {
            op: extrap(probes[L1]["coll"][op], probes[L2]["coll"][op])
            for op in COLLECTIVE_OPS
        }
        coll_x["total"] = sum(coll_x.values())

        rec.update(
            status="ok",
            step=step_name,
            window=ispec.runtime_window(cfg, shape),
            lower_seconds=round(t_lower, 2),
            compile_seconds=round(t_compile, 2),
            flops_per_device=flops_x,
            bytes_accessed_per_device=bytes_x,
            collectives=coll_x,
            probe_depths=[L1, L2],
            raw_scan_costs={
                "flops": float(cost.get("flops", -1.0)),
                "bytes_accessed": float(cost.get("bytes accessed", -1.0)),
                "collectives": coll,
            },
            memory={
                "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
            },
            param_count=cfg.param_count(),
            param_count_active=cfg.param_count(active_only=True),
        )
        if keep_hlo and out_dir:
            hp = os.path.join(out_dir, f"{arch}_{shape_name}_{mesh_name}.hlo")
            with open(hp, "w") as f:
                f.write(hlo)
    except Exception as e:  # noqa: BLE001
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-3000:]
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(INPUT_SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--keep-hlo", action="store_true")
    ap.add_argument("--fsdp", choices=["on", "off"], default="on")
    ap.add_argument("--moe-combine", choices=["psum_f32", "psum_bf16", "scatter"],
                    default="psum_f32")
    ap.add_argument("--fused-probe", action="store_true")
    ap.add_argument("--tag", default="",
                    help="suffix for output files (perf variants)")
    args = ap.parse_args()

    variant = {
        "fsdp": args.fsdp == "on",
        "moe_combine": args.moe_combine,
        "fused_probe": args.fused_probe,
    }

    os.makedirs(args.out, exist_ok=True)
    pairs = []
    archs = ASSIGNED_ARCHS if (args.all or args.arch is None) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multipod]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                pairs.append((a, s, mp))

    for a, s, mp in pairs:
        rec = run_one(a, s, mp, args.out, keep_hlo=args.keep_hlo,
                      variant=variant, tag=args.tag)
        suffix = f"_{args.tag}" if args.tag else ""
        name = f"{a}_{s}_{'pod2x16x16' if mp else 'pod16x16'}{suffix}.json"
        with open(os.path.join(args.out, name), "w") as f:
            json.dump(rec, f, indent=2)
        status = rec["status"]
        extra = ""
        if status == "ok":
            extra = (f"flops/dev={rec['flops_per_device']:.3e} "
                     f"coll={rec['collectives']['total']:.3e}B "
                     f"compile={rec['compile_seconds']}s")
        elif status == "error":
            extra = rec["error"]
        else:
            extra = rec["reason"]
        print(f"[{status:7s}] {a} x {s} x {'2x16x16' if mp else '16x16'}  {extra}",
              flush=True)


if __name__ == "__main__":
    main()
