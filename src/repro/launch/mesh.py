"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing never touches jax
device state — the dry-run must set XLA_FLAGS before any device query.
"""
from __future__ import annotations

import jax

from repro.sharding.partition import ShardCtx


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (TPU v5e pod slice).
    Multi-pod: (pod=2, data=16, model=16) = 512 chips; the ``pod`` axis is
    pure data parallelism (DCN-crossing gradient all-reduce)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_ctx(*, multi_pod: bool = False) -> ShardCtx:
    mesh = make_production_mesh(multi_pod=multi_pod)
    batch_axes = ("pod", "data") if multi_pod else ("data",)
    return ShardCtx(mesh=mesh, batch_axes=batch_axes, model_axis="model")


def make_device_ctx(data: int, model: int, *, fsdp: bool = False) -> ShardCtx:
    """(data x model) mesh over the currently visible devices — real chips
    or ``--xla_force_host_platform_device_count`` simulated ones (the
    mesh-equivalence tests and the DP scaling benchmark use the latter).

    Serving default is ``fsdp=False``: decode re-gathers every weight every
    step under FSDP, so weights are replicated over ``data`` and only
    tensor-parallel over ``model`` (see ``ShardCtx.fsdp``).
    """
    mesh = jax.make_mesh((data, model), ("data", "model"))
    return ShardCtx(mesh=mesh, batch_axes=("data",), model_axis="model",
                    fsdp=fsdp)


def local_ctx() -> ShardCtx:
    """Single-device ctx for CPU tests/examples."""
    return ShardCtx(mesh=None)
