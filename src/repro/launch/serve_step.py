"""The jitted EAT-monitored decode step — ONE program, two drivers.

``make_eat_step`` builds the canonical single-token serving step: next-token
sampling, the non-committing ``</think>``+prefix probe, the fused entropy
reduction, the EMA mean/variance update, and the latched early-exit decision,
all as masked array ops over a ``MonitorState``.  It is the shared core that

  * the decode-shape dry-runs lower (via ``make_serve_step``, which fixes
    ``active = ones`` and an every-token evaluation schedule), and
  * ``ReasoningEngine`` scans inside its device-resident ``decode_chunk``
    (``jax.lax.while_loop`` over this step, one host sync per chunk).

so the program the roofline analyses cost out is the program the engine
actually dispatches.

Per-sequence adaptivity in a batched SPMD step: finished sequences ride
along with ``active=False`` — their monitor state freezes (``update`` masks
by ``due & active``) and their cache writes are don't-cares (nothing reads a
finished sequence's future slots).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.eat import ProbeSpec, eval_eat
from repro.core.monitor import MonitorState, ReasoningMonitor
from repro.core.stopping import EATStopper
from repro.models.model import Model
from repro.serving.sampler import SamplerConfig, sample


@dataclasses.dataclass(frozen=True)
class ServeStepConfig:
    window: int = 0
    probe: ProbeSpec = ProbeSpec((1, 6))        # </think> + "final answer:" prefix
    stopper: EATStopper = EATStopper(alpha=0.2, delta=1e-3)
    sampler: SamplerConfig = SamplerConfig()
    with_probe: bool = True
    # §Perf: fuse the probe into the decode forward (one weight pass per
    # step instead of two; see Model.decode_and_probe)
    fused_probe: bool = False


def serve_monitor(scfg: ServeStepConfig) -> ReasoningMonitor:
    """The dry-run's evaluation schedule: probe every token, no warmup —
    the most expensive (upper-bound) configuration of the monitored step."""
    return ReasoningMonitor(stopper=scfg.stopper, probe=scfg.probe,
                            schedule="every_n", every_n=1, min_evals=0)


def make_eat_step(
    model: Model,
    monitor: ReasoningMonitor | None,
    sampler: SamplerConfig,
    *,
    window: int | None = None,
    probe_cond: bool = True,
    fused_probe: bool = False,
):
    """Build ``step(params, cache, token, pos1d, mon, active, rng)``
    -> ``(next_token, cache, mon, stop, rng)``.

    token/pos1d: (B,1); mon: MonitorState; active: (B,) bool.  ``stop`` is
    the latched per-sequence exit mask (``mon.stop_flag``).

    ``probe_cond=True`` wraps the probe+update in ``lax.cond`` on
    ``(due & active).any()`` so chunks where no sequence hits an evaluation
    point pay zero probe FLOPs (the engine's sparse-schedule case);
    ``probe_cond=False`` probes unconditionally (the dry-run's every-token
    schedule, where the cond would always take the probe branch anyway).
    """
    cfg = model.cfg

    def _positions(pos1d):
        if cfg.mrope_sections:
            return jnp.broadcast_to(pos1d[..., None], pos1d.shape + (3,))
        return pos1d

    def step(params, cache, token, pos1d, mon: MonitorState, active, rng):
        if monitor is not None and fused_probe:
            B = token.shape[0]
            m = len(monitor.probe)
            probe_toks = jnp.broadcast_to(
                jnp.asarray(monitor.probe.tokens, jnp.int32), (B, m)
            )
            pos_all = pos1d[:, :1] + jnp.arange(1 + m, dtype=jnp.int32)[None]
            logits, eat, cache = model.decode_and_probe(
                params, token, _positions(pos_all), pos_all, cache, probe_toks,
                window=window,
            )
            rng, sub = jax.random.split(rng)
            nxt = sample(sub, logits[:, -1], cfg.vocab, sampler)
            mon = monitor.update(mon, eat, monitor.due(mon, nxt), active)
            return nxt, cache, mon, mon.stop_flag, rng

        logits, cache = model.decode_step(
            params, token, _positions(pos1d), pos1d, cache, window=window
        )
        rng, sub = jax.random.split(rng)
        nxt = sample(sub, logits[:, -1], cfg.vocab, sampler)
        if monitor is None:
            return nxt, cache, mon, jnp.zeros(nxt.shape, bool), rng

        next_pos = pos1d[:, -1] + 1
        eat_fn = lambda: eval_eat(model, params, cache, monitor.probe, next_pos)  # noqa: E731
        mon = monitor.observe(mon, eat_fn, nxt, active, lazy=probe_cond)
        return nxt, cache, mon, mon.stop_flag, rng

    return step


def make_serve_step(model: Model, scfg: ServeStepConfig):
    """Dry-run adapter: the 6-arg signature the roofline shapes lower.

    ``mon`` is a ``MonitorState`` (see ``serve_monitor`` for the struct);
    all sequences are treated as active.
    """
    monitor = serve_monitor(scfg) if scfg.with_probe else None
    step = make_eat_step(
        model, monitor, scfg.sampler, window=scfg.window,
        probe_cond=False, fused_probe=scfg.fused_probe,
    )

    def serve_step(params, cache, token, pos1d, mon: MonitorState, rng):
        """token/pos1d: (B,1).  Returns (next_token, cache, mon, stop, rng)."""
        active = jnp.ones(token.shape[:1], bool)
        return step(params, cache, token, pos1d, mon, active, rng)

    return serve_step
