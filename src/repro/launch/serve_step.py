"""DEPRECATED compat shim — import from ``repro.serving.executor`` instead.

The serve-step programs live in ``repro.serving.executor``; this module
re-exports them for out-of-tree callers of the pre-refactor API and will be
removed once none remain.  No in-tree code imports it (grep before adding a
new importer — add it to ``repro.serving.executor`` instead).

The canonical single-token EAT step (``make_eat_step``) and the dry-run's
lowerable program (``build_serve_step_program``) moved into the executor
layer so exactly ONE serve-step definition exists in the tree: the program
the decode-shape dry-runs lower and cost out is the program the engine's
device-resident chunks dispatch (docs/architecture.md).

Note this is a partial shim: the old ``make_serve_step`` (bare step
function, no jit/shardings) was deliberately REMOVED, not re-exported —
its jitting lived in ``launch.dryrun``, which is exactly the duplicate
program construction this refactor eliminates.  Callers lower
``build_serve_step_program`` instead.
"""
from repro.serving.executor import (  # noqa: F401
    ServeStepConfig,
    build_serve_step_program,
    make_eat_step,
    serve_monitor,
)

__all__ = ["ServeStepConfig", "build_serve_step_program", "make_eat_step",
           "serve_monitor"]
