"""The jitted serving step: decode one token + the paper's EAT machinery.

This is what the decode-shape dry-runs lower: a *full* EAT-monitored decode
step — next-token sampling, the non-committing ``</think>``+prefix probe,
the fused entropy reduction, the EMA mean/variance update, and the
early-exit decision — as one SPMD program.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.eat import ProbeSpec, eval_eat
from repro.core.ema import ema_update
from repro.core.stopping import EATState, EATStopper
from repro.models.model import Model
from repro.serving.sampler import SamplerConfig, sample


@dataclasses.dataclass(frozen=True)
class ServeStepConfig:
    window: int = 0
    probe: ProbeSpec = ProbeSpec((1, 6))        # </think> + "final answer:" prefix
    stopper: EATStopper = EATStopper(alpha=0.2, delta=1e-3)
    sampler: SamplerConfig = SamplerConfig()
    with_probe: bool = True
    # §Perf: fuse the probe into the decode forward (one weight pass per
    # step instead of two; see Model.decode_and_probe)
    fused_probe: bool = False


def make_serve_step(model: Model, scfg: ServeStepConfig):
    cfg = model.cfg

    def _positions(pos1d):
        if cfg.mrope_sections:
            return jnp.broadcast_to(pos1d[..., None], pos1d.shape + (3,))
        return pos1d

    def serve_step(params, cache, token, pos1d, mon: EATState, rng):
        """token/pos1d: (B,1).  Returns (next_token, cache, mon, stop, rng)."""
        if scfg.with_probe and scfg.fused_probe:
            B = token.shape[0]
            m = len(scfg.probe)
            probe_toks = jnp.broadcast_to(
                jnp.asarray(scfg.probe.tokens, jnp.int32), (B, m)
            )
            pos_all = pos1d[:, :1] + jnp.arange(1 + m, dtype=jnp.int32)[None]
            logits, eat, cache = model.decode_and_probe(
                params, token, _positions(pos_all), pos_all, cache, probe_toks,
                window=scfg.window,
            )
            rng, sub = jax.random.split(rng)
            nxt = sample(sub, logits[:, -1], cfg.vocab, scfg.sampler)
            mon = EATState(ema=ema_update(mon.ema, eat, scfg.stopper.alpha), last=eat)
            return nxt, cache, mon, scfg.stopper.should_stop(mon), rng

        logits, cache = model.decode_step(
            params, token, _positions(pos1d), pos1d, cache, window=scfg.window
        )
        rng, sub = jax.random.split(rng)
        nxt = sample(sub, logits[:, -1], cfg.vocab, scfg.sampler)
        if scfg.with_probe:
            next_pos = pos1d[:, -1] + 1
            eat = eval_eat(model, params, cache, scfg.probe, next_pos)
            mon = EATState(ema=ema_update(mon.ema, eat, scfg.stopper.alpha), last=eat)
            stop = scfg.stopper.should_stop(mon)
        else:
            stop = jnp.zeros(nxt.shape, bool)
        return nxt, cache, mon, stop, rng

    return serve_step
