"""ShapeDtypeStruct stand-ins for every (architecture x input-shape) pair.

No device allocation ever happens here — everything is abstract shapes for
``jax.jit(...).lower()``.  The modality frontends are stubs per the
assignment: audio provides (B, encoder_len, d) frame embeddings, VLM
provides (B, n_image_patches, d) projected patch embeddings.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import INPUT_SHAPES, InputShape, ModelConfig
from repro.serving.cache import alloc_cache

SDS = jax.ShapeDtypeStruct

LONG_CTX_WINDOW = 8192   # SWA window substituted at long_500k (DESIGN.md §6)


def runtime_window(cfg: ModelConfig, shape: InputShape) -> int:
    """Attention window used at this shape (0 = full attention)."""
    if shape.name == "long_500k" and cfg.arch_type not in ("ssm",):
        return LONG_CTX_WINDOW
    return cfg.sliding_window


def cache_capacity(cfg: ModelConfig, shape: InputShape) -> int:
    w = runtime_window(cfg, shape)
    if w:
        return w
    return shape.seq_len


def skip_reason(cfg: ModelConfig, shape: InputShape) -> str | None:
    if cfg.name == "seamless-m4t-large-v2" and shape.name == "long_500k":
        return ("encoder-decoder speech translation has no meaningful 512k-token "
                "target-side decode (DESIGN.md §6)")
    return None


def _pos_struct(cfg: ModelConfig, B: int, S: int):
    if cfg.mrope_sections:
        return SDS((B, S, 3), jnp.int32)
    return SDS((B, S), jnp.int32)


def train_batch_specs(cfg: ModelConfig, shape: InputShape) -> dict:
    B, S = shape.global_batch, shape.seq_len
    d = cfg.d_model
    batch: dict = {
        "targets": SDS((B, S), jnp.int32),
        "loss_mask": SDS((B, S), jnp.float32),
        "positions": _pos_struct(cfg, B, S),
        "pos1d": SDS((B, S), jnp.int32),
    }
    if cfg.arch_type == "vlm":
        P = cfg.n_image_patches
        batch["tokens"] = SDS((B, S - P), jnp.int32)
        batch["image_embeds"] = SDS((B, P, d), jnp.bfloat16)
    elif cfg.arch_type == "encdec":
        batch["tokens"] = SDS((B, S), jnp.int32)
        batch["frames"] = SDS((B, cfg.encoder_len, d), jnp.bfloat16)
    else:
        batch["tokens"] = SDS((B, S), jnp.int32)
    return batch


def cache_specs(cfg: ModelConfig, batch: int, capacity: int) -> dict:
    """Abstract cache pytree via eval_shape over the real allocator."""
    return jax.eval_shape(lambda: alloc_cache(cfg, batch, capacity))


def prefill_specs(cfg: ModelConfig, shape: InputShape) -> dict:
    B, S = shape.global_batch, shape.seq_len
    d = cfg.d_model
    spec = {
        "tokens": SDS((B, S), jnp.int32),
        "positions": _pos_struct(cfg, B, S),
        "pos1d": SDS((B, S), jnp.int32),
        "cache": cache_specs(cfg, B, cache_capacity(cfg, shape)),
    }
    if cfg.arch_type == "encdec":
        spec["frames"] = SDS((B, cfg.encoder_len, d), jnp.bfloat16)
    if cfg.arch_type == "vlm":
        P = cfg.n_image_patches
        spec["tokens"] = SDS((B, S - P), jnp.int32)
        spec["image_embeds"] = SDS((B, P, d), jnp.bfloat16)
    return spec


def decode_specs(cfg: ModelConfig, shape: InputShape) -> dict:
    """serve_step inputs: ONE new token against a seq_len-deep cache
    (ring-buffer of ``window`` slots when SWA is substituted)."""
    B = shape.global_batch
    return {
        "token": SDS((B, 1), jnp.int32),
        "pos1d": SDS((B, 1), jnp.int32),
        "cache": cache_specs(cfg, B, cache_capacity(cfg, shape)),
        "rng": SDS((2,), jnp.uint32),
    }


def params_specs(model) -> dict:
    return jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
