"""Production training launcher.

On real TPU hardware this runs the pjit train step on the production mesh
for any assigned architecture:

  python -m repro.launch.train --arch qwen3-1.7b --steps 100 [--multipod]

On CPU (this container) use ``--local`` to train reduced/tiny configs —
the same code path minus the mesh (examples/train_reasoner.py wraps it for
the synthetic reasoning model).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.data.pipeline import device_put_batch, train_batches
from repro.data.synthetic import ChainTask
from repro.launch.mesh import local_ctx, make_ctx
from repro.models import Model
from repro.training.checkpoint import save_checkpoint
from repro.training.optimizer import AdamWConfig
from repro.training.train_loop import (
    TrainConfig,
    init_train_state,
    jit_train_step,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--local", action="store_true",
                    help="single-device (CPU) run on the reduced config")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced or args.local:
        cfg = cfg.reduced()
    ctx = local_ctx() if args.local else make_ctx(multi_pod=args.multipod)
    model = Model(cfg, ctx, attn_impl="xla")

    task = ChainTask(seq_len=args.seq or 96)
    state = init_train_state(model, jax.random.PRNGKey(0))
    tcfg = TrainConfig(opt=AdamWConfig(lr=args.lr, total_steps=args.steps),
                       remat=not args.local)
    it = train_batches(task, args.batch, seed=0)
    batch0 = device_put_batch(model, next(it))
    step_fn = jit_train_step(model, tcfg, state, batch0)

    t0 = time.time()
    for i, batch in zip(range(args.steps), it):
        batch = device_put_batch(model, batch)
        state, metrics = step_fn(state, batch)
        if i % 20 == 0:
            print(f"step {i}: loss={float(metrics['loss']):.4f} "
                  f"acc={float(metrics['accuracy']):.3f} "
                  f"({(time.time()-t0)/(i+1):.2f}s/step)", flush=True)
    if args.ckpt:
        save_checkpoint(args.ckpt, state.params)
        print(f"saved {args.ckpt}")


if __name__ == "__main__":
    main()
