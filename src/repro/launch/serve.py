"""Production serving launcher: batched EAT-monitored reasoning serving.

  python -m repro.launch.serve --arch tiny-reasoner --local \
      --ckpt artifacts/tiny_reasoner.ckpt --batch 8 --delta 1e-3

On TPU the same launcher builds the production mesh and shards the serve
state (the dry-run proves every assigned architecture lowers; this is the
runtime equivalent).  On CPU it serves the synthetic-task models.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.core.eat import make_probe
from repro.core.monitor import ReasoningMonitor
from repro.core.stopping import EATStopper
from repro.data.synthetic import ChainTask, Tokens
from repro.launch.mesh import local_ctx, make_ctx, make_device_ctx
from repro.models import Model
from repro.serving.engine import EngineConfig, ReasoningEngine
from repro.serving.sampler import SamplerConfig
from repro.training.checkpoint import load_checkpoint


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tiny-reasoner")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--delta", type=float, default=1e-3)
    ap.add_argument("--alpha", type=float, default=0.2)
    ap.add_argument("--budget", type=int, default=96)
    ap.add_argument("--local", action="store_true")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--mesh", default=None, metavar="DATAxMODEL",
                    help="serve on a (data x model) mesh over the visible "
                         "devices, e.g. --mesh 4x2 (overrides --local / the "
                         "production mesh)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="top-k sampling cutoff (0 = off)")
    ap.add_argument("--typical-p", type=float, default=1.0,
                    help="locally-typical sampling mass (1 = off)")
    ap.add_argument("--min-p", type=float, default=0.0,
                    help="min-p sampling cutoff relative to the max-prob "
                         "token (0 = off)")
    ap.add_argument("--attn-impl",
                    choices=["gather", "auto", "xla", "pallas"],
                    default="gather",
                    help="decode/probe attention implementation: gather "
                         "(materialize the paged cache's logical view) or "
                         "the page-native path (auto/xla/pallas — K/V read "
                         "straight off the page pools through the mapped-"
                         "page list, O(mapped pages) per token; 'pallas' "
                         "runs the TPU kernel, in interpret mode on CPU — "
                         "docs/serving.md)")
    ap.add_argument("--chunk", type=int, default=32,
                    help="decode steps per jitted dispatch")
    ap.add_argument("--requests", type=int, default=0,
                    help="serve N queued requests through --batch slots "
                         "with continuous batching (0 = single batch)")
    ap.add_argument("--cache", choices=["ring", "paged"], default="ring",
                    help="KV-cache backend for --requests serving: ring "
                         "(dense, batch-lifetime capacity) or paged (block "
                         "pool, per-block admission — docs/serving.md)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="paged backend: logical slots per physical page")
    ap.add_argument("--num-pages", type=int, default=0,
                    help="paged backend: physical page-pool size "
                         "(0 = ring-equivalent auto sizing)")
    ap.add_argument("--overlap", choices=["off", "on"], default="off",
                    help="--requests serving loop: off = synchronous chunk "
                         "boundaries (dispatch, block, harvest), on = the "
                         "double-buffered pipeline (chunk N+1 dispatched "
                         "while chunk N is harvested; bit-identical token "
                         "streams under greedy sampling, proxy exits land "
                         "at most one chunk later — docs/serving.md "
                         "§Overlapped serving)")
    ap.add_argument("--monitor", choices=["self", "proxy"], default="self",
                    help="EAT monitor tier: self (white-box, probe inlined "
                         "in the decode chunk) or proxy (black-box, a "
                         "second model shadows the emitted stream — "
                         "docs/serving.md §Black-box monitoring)")
    ap.add_argument("--proxy-config", default=None, metavar="ARCH",
                    help="monitor=proxy: proxy model architecture "
                         "(default: --arch, i.e. a same-family twin)")
    ap.add_argument("--proxy-ckpt", default=None,
                    help="monitor=proxy: proxy checkpoint (default: random "
                         "weights, seeded differently from the generator)")
    ap.add_argument("--proxy-mesh", default=None, metavar="DATAxMODEL",
                    help="monitor=proxy: give the proxy its own (smaller) "
                         "mesh over the visible devices, e.g. 1x2 "
                         "(default: share the generator's context)")
    args = ap.parse_args()

    if args.monitor == "proxy" and not args.requests:
        ap.error("--monitor proxy serves through the scheduler: pass "
                 "--requests N")
    if args.overlap == "on" and not args.requests:
        ap.error("--overlap on applies to the --requests serving loop: "
                 "pass --requests N")
    if args.monitor != "proxy" and (args.proxy_config or args.proxy_ckpt
                                    or args.proxy_mesh):
        ap.error("--proxy-config/--proxy-ckpt/--proxy-mesh only apply with "
                 "--monitor proxy (default monitor is 'self')")

    cfg = get_config(args.arch)
    if args.mesh:
        d, m = (int(x) for x in args.mesh.lower().split("x"))
        ctx = make_device_ctx(d, m)
    elif args.local:
        ctx = local_ctx()
    else:
        ctx = make_ctx(multi_pod=args.multipod)
    model = Model(cfg, ctx, attn_impl="xla")
    if args.ckpt:
        like = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
        params = load_checkpoint(args.ckpt, like)
    else:
        print("WARNING: no checkpoint — random weights")
        params = model.init(jax.random.PRNGKey(0))

    from repro.serving.cache import CacheConfig

    ecfg = EngineConfig(
        max_reasoning_tokens=args.budget, capacity=args.budget + 128,
        pad_id=Tokens.PAD, end_think_id=Tokens.END_THINK,
        newline_id=Tokens.NEWLINE, eos_id=Tokens.EOS,
        sampler=SamplerConfig(temperature=0.6, top_p=0.95,
                              top_k=args.top_k, typical_p=args.typical_p,
                              min_p=args.min_p),
        cache=CacheConfig(attn_impl=args.attn_impl),
    )
    monitor = ReasoningMonitor(
        stopper=EATStopper(alpha=args.alpha, delta=args.delta),
        probe=make_probe(Tokens.END_THINK, (Tokens.ANS,)),
        newline_id=Tokens.NEWLINE,
    )
    ecfg.chunk_len = args.chunk

    proxy = None
    if args.monitor == "proxy":
        from repro.serving.proxy import ProxyConfig

        proxy_cfg = get_config(args.proxy_config or args.arch)
        if proxy_cfg.vocab != cfg.vocab:
            raise SystemExit(f"proxy arch {proxy_cfg.name} must share the "
                             f"generator's tokenizer (vocab {cfg.vocab}, "
                             f"got {proxy_cfg.vocab})")
        if args.proxy_mesh:
            d, m = (int(x) for x in args.proxy_mesh.lower().split("x"))
            proxy_ctx = make_device_ctx(d, m)
        else:
            proxy_ctx = ctx
        proxy_model = Model(proxy_cfg, proxy_ctx, attn_impl="xla")
        if args.proxy_ckpt:
            like = jax.eval_shape(
                lambda: proxy_model.init(jax.random.PRNGKey(0)))
            proxy_params = load_checkpoint(args.proxy_ckpt, like)
        else:
            print("WARNING: no proxy checkpoint — random proxy weights")
            proxy_params = proxy_model.init(jax.random.PRNGKey(1))
        proxy = ProxyConfig(model=proxy_model, params=proxy_params)

    task = ChainTask()
    if args.requests:
        # continuous batching: args.batch slots over a longer request
        # queue; early-exiting sequences free their slot for the next
        # prompt.  The shared ring pointer advances for the whole run, so
        # (logical) capacity must cover the batch-lifetime worst case, not
        # one budget; with --cache paged that capacity is int32 metadata
        # and the PHYSICAL footprint is --num-pages pages of live KV.  The
        # cache config must be final BEFORE the engine is built — the
        # engine bakes --attn-impl/--page-size into its model.
        from repro.serving.scheduler import SlotScheduler

        batch = task.serve_batch(np.random.default_rng(0), args.requests)
        ecfg.capacity = SlotScheduler.required_capacity(
            batch["prompts"].shape[1], args.requests, args.batch, args.budget
        )
        if args.overlap == "on":
            # the overlapped loop's ring guard adds one in-flight chunk to
            # its (host-mirror) pointer estimate — give it that headroom
            ecfg.capacity += args.chunk
        ecfg.cache = CacheConfig(kind=args.cache, page_size=args.page_size,
                                 num_pages=args.num_pages,
                                 attn_impl=args.attn_impl)

    engine = ReasoningEngine(model, params, ecfg, monitor, proxy=proxy)

    if args.requests:
        results = engine.serve(batch["prompts"], batch["prompt_len"],
                               jax.random.PRNGKey(0), batch_size=args.batch,
                               answer_len=4,
                               overlap=args.overlap == "on")
        ans = np.array([ChainTask.extract_answer(r["answer_tokens"][None])[0]
                        for r in results])
        n = np.array([r["n_reasoning"] for r in results])
        print(f"served {args.requests} requests through {args.batch} slots "
              f"(monitor={engine.monitor_mode})")
        print(f"answers: {ans}  truth: {batch['answers']}")
        print(f"correct: {(ans == batch['answers']).mean():.2f}  "
              f"reasoning tokens: total={n.sum()} per-q={n}")
        return

    batch = task.serve_batch(np.random.default_rng(0), args.batch)
    st = engine.start(jnp.asarray(batch["prompts"]), jnp.asarray(batch["prompt_len"]),
                      jax.random.PRNGKey(0))
    st = engine.reason(st)
    toks, _ = engine.force_answer(st, 4)
    ans = ChainTask.extract_answer(np.asarray(toks))
    n = np.asarray(st.n_reasoning)
    print(f"answers: {ans}  truth: {batch['answers']}")
    print(f"correct: {(ans == batch['answers']).mean():.2f}  "
          f"reasoning tokens: total={n.sum()} per-q={n}")
    print(f"exit via EAT: {np.asarray(st.monitor.stop_flag)}")


if __name__ == "__main__":
    main()
