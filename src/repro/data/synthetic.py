"""Synthetic "overthinking" chain-of-thought task.

This is the offline stand-in for MATH-500/AIME (DESIGN.md §5): a task whose
*distribution dynamics* match the paper's §3.3 observation — Pass@1 climbs,
saturates at a per-question difficulty-dependent point, and further
reasoning is pure verification.

Task.  A question hides a digit chain: s_0 = 0, s_i = (e_i + 2 s_{i-1}) mod
10, where e_1..e_k are given *encrypted* in the prompt.  The answer is s_k.
Because s_i depends on s_{i-1}, decoding clue i requires the partial result
— a depth-k sequential computation a small transformer cannot shortcut in
one forward pass; it must "reason" step by step, writing each s_i into its
chain of thought:

  prompt:    Q <k> e_1 .. e_k <think>
  reasoning: STEP <1> <s_1> \n\n  STEP <2> <s_2> \n\n ... STEP <k> <s_k> \n\n
  overthink: CHECK <j> <s_j> \n\n  (x E extra verification lines)
  answer:    </think> ANS <s_k> <eos>

Training mixes (a) full chains with E ~ U{0..max_extra} verification lines
(the overthinking behavior §3.3 / App. J), and (b) premature-exit chains cut
at j < k lines whose answer label is still the true s_k — unlearnable from
a truncated prefix, which teaches the model a *calibrated* (high-entropy)
answer distribution after insufficient reasoning.  Exactly this calibration
is what makes EAT informative (paper App. C, question 3).

Probe: [</think>, ANS] — ANS is the "The final answer:" prefix string of
Eq. (13); the next token is the answer digit, so EAT measures the answer
posterior's entropy: ~ln10 before step k, ~0 after.  Pass@1 = fraction of
forced rollouts whose digit equals s_k (Eq. 9).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import numpy as np


class Tokens:
    PAD = 0
    END_THINK = 1          # </think>
    NEWLINE = 2            # "\n\n" paragraph separator
    EOS = 3
    BEGIN_THINK = 4        # <think>
    Q = 5
    ANS = 6                # "The final answer:" prefix
    STEP = 7
    CHECK = 8
    D0 = 9                 # digits 0..9 -> ids 9..18
    VOCAB = 32             # a few unused ids as slack

    @staticmethod
    def digit(d: int) -> int:
        return Tokens.D0 + int(d)

    @staticmethod
    def is_digit(t) -> bool:
        return Tokens.D0 <= t < Tokens.D0 + 10


@dataclasses.dataclass(frozen=True)
class ChainTask:
    min_k: int = 2
    max_k: int = 9
    max_extra: int = 14         # max verification lines (overthinking)
    p_early: float = 0.3        # premature-exit training mixture
    seq_len: int = 128

    # ----------------------------------------------------------- instance
    def sample_instance(self, rng: np.random.Generator, k: int | None = None) -> dict:
        if k is None:
            k = int(rng.integers(self.min_k, self.max_k + 1))
        e = rng.integers(0, 10, size=k)
        s = np.zeros(k + 1, np.int64)
        for i in range(1, k + 1):
            s[i] = (e[i - 1] + 2 * s[i - 1]) % 10
        return {"k": k, "e": e, "s": s, "answer": int(s[k])}

    def prompt_tokens(self, inst: dict) -> list[int]:
        T = Tokens
        return [T.Q, T.digit(inst["k"])] + [T.digit(x) for x in inst["e"]] + [T.BEGIN_THINK]

    def step_line(self, i: int, s_i: int) -> list[int]:
        T = Tokens
        return [T.STEP, T.digit(i % 10), T.digit(s_i), T.NEWLINE]

    def check_line(self, j: int, s_j: int) -> list[int]:
        T = Tokens
        return [T.CHECK, T.digit(j % 10), T.digit(s_j), T.NEWLINE]

    # ----------------------------------------------------------- training
    def sample_sequence(self, rng: np.random.Generator) -> np.ndarray:
        T = Tokens
        inst = self.sample_instance(rng)
        k, s = inst["k"], inst["s"]
        toks = self.prompt_tokens(inst)
        if rng.random() < self.p_early and k > 1:
            j = int(rng.integers(0, k))          # premature exit after j lines
            for i in range(1, j + 1):
                toks += self.step_line(i, s[i])
        else:
            for i in range(1, k + 1):
                toks += self.step_line(i, s[i])
            extra = int(rng.integers(0, self.max_extra + 1))
            for _ in range(extra):
                j = int(rng.integers(1, k + 1))
                toks += self.check_line(j, s[j])
        toks += [T.END_THINK, T.ANS, T.digit(inst["answer"]), T.EOS]
        arr = np.full(self.seq_len, T.PAD, np.int32)
        arr[: min(len(toks), self.seq_len)] = toks[: self.seq_len]
        return arr

    def batch(self, rng: np.random.Generator, batch_size: int) -> dict:
        seqs = np.stack([self.sample_sequence(rng) for _ in range(batch_size)])
        tokens = seqs[:, :-1]
        targets = seqs[:, 1:]
        mask = (targets != Tokens.PAD).astype(np.float32)
        S = tokens.shape[1]
        pos = np.broadcast_to(np.arange(S, dtype=np.int32), tokens.shape)
        return {
            "tokens": tokens,
            "targets": targets,
            "loss_mask": mask,
            "positions": pos.copy(),
            "pos1d": pos.copy(),
        }

    # ----------------------------------------------------------- serving
    def serve_batch(self, rng: np.random.Generator, batch_size: int,
                    k: int | None = None) -> dict:
        """Left-padded prompts + ground truth for the serving engine."""
        insts = [self.sample_instance(rng, k=k) for _ in range(batch_size)]
        prompts = [self.prompt_tokens(i) for i in insts]
        L = max(len(p) for p in prompts)
        out = np.full((batch_size, L), Tokens.PAD, np.int32)
        lens = np.zeros(batch_size, np.int32)
        for b, p in enumerate(prompts):
            out[b, L - len(p):] = p             # LEFT padding
            lens[b] = len(p)
        return {
            "prompts": out,
            "prompt_len": lens,
            "answers": np.array([i["answer"] for i in insts], np.int32),
            "k": np.array([i["k"] for i in insts], np.int32),
        }

    # ----------------------------------------------------------- metrics
    @staticmethod
    def extract_answer(rollout: np.ndarray) -> np.ndarray:
        """rollout: (B, n) forced-rollout tokens (starting after </think>).
        Returns (B,) digit (0..9) or -1 if malformed.  The canonical format
        is [ANS, digit, EOS, ...]; we scan for the first digit after ANS."""
        B, n = rollout.shape
        out = np.full(B, -1, np.int64)
        for b in range(B):
            seen_ans = False
            for t in rollout[b]:
                if t == Tokens.ANS:
                    seen_ans = True
                elif seen_ans and Tokens.is_digit(t):
                    out[b] = int(t) - Tokens.D0
                    break
                elif t == Tokens.EOS:
                    break
        return out
