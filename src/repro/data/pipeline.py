"""Host data pipeline: batch iterator + device placement with shardings."""
from __future__ import annotations

from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.synthetic import ChainTask
from repro.models.model import Model
from repro.training.train_loop import batch_pspecs


def train_batches(task: ChainTask, batch_size: int, seed: int = 0) -> Iterator[dict]:
    rng = np.random.default_rng(seed)
    while True:
        yield task.batch(rng, batch_size)


def device_put_batch(model: Model, batch: dict) -> dict:
    ctx = model.ctx
    if ctx.mesh is None:
        return jax.tree_util.tree_map(jnp.asarray, batch)
    specs = batch_pspecs(model, batch)
    return jax.tree_util.tree_map(
        lambda x, s: jax.device_put(jnp.asarray(x), ctx.sharding(s)), batch, specs
    )
