from repro.data.synthetic import ChainTask, Tokens  # noqa: F401
