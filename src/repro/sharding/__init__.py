from repro.sharding.partition import ShardCtx, param_pspecs  # noqa: F401
