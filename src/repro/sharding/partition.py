"""Sharding rules: logical parameter layout -> mesh PartitionSpecs.

Strategy (DESIGN.md §7):
  * batch            -> (pod, data)          activations
  * d_model (weight reduction dims) -> data  (FSDP / ZeRO-3 style)
  * heads / d_ff / d_inner / vocab  -> model (tensor parallel), only when
    the dimension is divisible by the model-axis size; otherwise that dim
    stays unsharded and the weight is only FSDP-sharded (e.g. gemma-2b's
    8 q-heads / MQA kv=1 on a 16-wide model axis).
  * experts          -> model (expert parallel) AND expert d_model -> data
    at rest (236B must be 2D-sharded to fit); the MoE block re-gathers the
    ``data`` shards transiently (see models/moe.py).

Everything is name-based over the parameter pytree: init functions use
stable key names (wq/wk/wv/wo, w_up/w_gate/w_down, experts/*, ssm w_*),
and ``param_pspecs`` maps each path to a PartitionSpec.  Stacked-layer
leading axes (from ``lax.scan`` stacking) are detected via the ``layers/``
path prefix and get a leading ``None``.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.utils.treeutil import tree_flatten_with_paths


@dataclasses.dataclass(frozen=True)
class ShardCtx:
    """Mesh + axis-name bundle threaded through model code.

    ``mesh=None`` means single-process local execution (tests): all
    constraints become no-ops and the MoE block runs its local path.
    """

    mesh: Optional[Mesh] = None
    batch_axes: tuple[str, ...] = ("data",)   # ('pod','data') for multi-pod
    model_axis: str = "model"
    # FSDP (ZeRO-3) sharding of weights over the data axis.  True for
    # training (optimizer state must be cut 256 ways); False for serving
    # (§Perf iteration: decode re-gathers every weight every step under
    # FSDP — replicating over `data` removes that all-gather entirely).
    fsdp: bool = True
    # MoE expert-combine collective (§Perf iteration on the MoE giants):
    #   psum_f32   — baseline: all-reduce the full f32 token tensor
    #   psum_bf16  — cast to bf16 before the all-reduce (2x bytes)
    #   scatter    — bf16 reduce-scatter over tokens onto the model axis
    #                (matches the sequence-parallel residual layout; ~4x)
    moe_combine: str = "psum_f32"

    @property
    def model_size(self) -> int:
        if self.mesh is None:
            return 1
        return self.mesh.shape[self.model_axis]

    @property
    def data_size(self) -> int:
        if self.mesh is None:
            return 1
        return int(np.prod([self.mesh.shape[a] for a in self.batch_axes]))

    @property
    def axis_names(self) -> tuple[str, ...]:
        return tuple(self.batch_axes) + (self.model_axis,)

    def batch_spec_entry(self):
        return self.batch_axes if len(self.batch_axes) > 1 else self.batch_axes[0]

    def batch_entry_for(self, batch: int):
        """PartitionSpec entry for a batch axis of size ``batch``: the data
        axes when the size divides them, else None (replicated — e.g. B=1
        slot-admission states, batch=1 long-context shapes).  THE single
        divisibility rule for every batch-dim spec in the tree."""
        return self.batch_spec_entry() if batch % self.data_size == 0 else None

    def wsc(self, x, spec: P):
        """with_sharding_constraint if a mesh is active, else identity."""
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(x, NamedSharding(self.mesh, spec))

    def sharding(self, spec: P) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, spec)


def _div(n: int, k: int) -> bool:
    return k > 0 and n % k == 0


def _spec_for(path: str, shape: tuple[int, ...], cfg: ModelConfig, ctx: ShardCtx) -> P:
    ms = ctx.model_size
    data = "data"  # FSDP always on the in-pod data axis only
    m = ctx.model_axis
    leaf = path.split("/")[-1]

    if ctx.mesh is None:
        return P()

    dsz = ctx.mesh.shape[data]

    def fsdp(n):
        if not ctx.fsdp:
            return None
        return data if _div(n, dsz) else None

    def tp(n):
        return m if _div(n, ms) else None

    # ---- embeddings
    if leaf == "embedding":
        return P(tp(shape[0]), fsdp(shape[1]))
    if leaf == "lm_head":
        return P(fsdp(shape[0]), tp(shape[1]))

    # ---- MoE experts (E, d, ff) / (E, ff, d)
    if "/experts/" in path or path.endswith("router"):
        if leaf == "router":
            return P(*( [None] * (len(shape) - 2) + [fsdp(shape[-2]), None] ))
        body = [tp(shape[-3]), None, None]
        if leaf in ("w_up", "w_gate"):
            body = [tp(shape[-3]), fsdp(shape[-2]), None]
        elif leaf == "w_down":
            body = [tp(shape[-3]), None, fsdp(shape[-1])]
        return P(*([None] * (len(shape) - 3) + body))

    # ---- attention
    if leaf == "wq":
        return P(*([None] * (len(shape) - 2)), fsdp(shape[-2]),
                 m if _div(cfg.n_heads, ms) else None)
    if leaf in ("wk", "wv"):
        return P(*([None] * (len(shape) - 2)), fsdp(shape[-2]),
                 m if _div(cfg.n_kv_heads, ms) else None)
    if leaf == "wo":
        return P(*([None] * (len(shape) - 2)),
                 m if _div(cfg.n_heads, ms) else None, fsdp(shape[-1]))
    if leaf in ("bq",):
        return P(*([None] * (len(shape) - 1)), m if _div(cfg.n_heads, ms) else None)
    if leaf in ("bk", "bv"):
        return P(*([None] * (len(shape) - 1)), m if _div(cfg.n_kv_heads, ms) else None)

    # ---- MLA
    if leaf in ("w_dq", "w_dkv", "w_kr"):
        return P(*([None] * (len(shape) - 2)), fsdp(shape[-2]), None)
    if leaf in ("w_uq", "w_uk", "w_uv"):
        return P(*([None] * (len(shape) - 2)), fsdp(shape[-2]),
                 m if _div(cfg.n_heads, ms) else None)

    # ---- dense MLP
    if leaf in ("w_up", "w_gate"):
        return P(*([None] * (len(shape) - 2)), fsdp(shape[-2]), tp(shape[-1]))
    if leaf == "w_down":
        return P(*([None] * (len(shape) - 2)), tp(shape[-2]), fsdp(shape[-1]))

    # ---- SSM (separated projections; d_inner / heads are model-sharded)
    if leaf in ("w_z", "w_x"):
        return P(*([None] * (len(shape) - 2)), fsdp(shape[-2]), tp(shape[-1]))
    if leaf in ("w_b", "w_c"):
        return P(*([None] * (len(shape) - 2)), fsdp(shape[-2]), None)
    if leaf == "w_dt":
        return P(*([None] * (len(shape) - 2)), fsdp(shape[-2]), tp(shape[-1]))
    if leaf in ("conv_x_w", "conv_x_b"):
        return P(*([None] * (len(shape) - 1)), tp(shape[-1]))
    if leaf in ("conv_bc_w", "conv_bc_b"):
        return P(*([None] * (len(shape) - 1)), None)
    if leaf in ("dt_bias", "A_log", "D"):
        return P(*([None] * (len(shape) - 1)), tp(shape[-1]))
    if leaf == "norm_w" and "ssm" in path:
        return P(*([None] * (len(shape) - 1)), tp(shape[-1]))
    if leaf == "out_proj":
        return P(*([None] * (len(shape) - 2)), tp(shape[-2]), fsdp(shape[-1]))

    # ---- everything else (norms, biases, scalars): replicated
    return P(*([None] * len(shape)))


def param_pspecs(params, cfg: ModelConfig, ctx: ShardCtx):
    """PartitionSpec pytree matching ``params``."""
    flat = tree_flatten_with_paths(params)
    specs = {}
    for path, leaf in flat:
        specs[path] = _spec_for(path, leaf.shape, cfg, ctx)
    # rebuild tree
    treedef = jax.tree_util.tree_structure(params)
    leaves_with_paths = tree_flatten_with_paths(params)
    spec_leaves = [specs[p] for p, _ in leaves_with_paths]
    return jax.tree_util.tree_unflatten(treedef, spec_leaves)


def param_shardings(params, cfg: ModelConfig, ctx: ShardCtx):
    if ctx.mesh is None:
        return None
    specs = param_pspecs(params, cfg, ctx)
    return jax.tree_util.tree_map(lambda s: NamedSharding(ctx.mesh, s), specs)


def proxy_stream_pspecs(ctx: ShardCtx, batch: int):
    """PartitionSpecs for the generator-stream inputs of the proxy shadow
    program (``serving.executor.ProxyExecutor.observe_chunk``): the emitted
    token buffer (B, T) and the per-row offset/count vectors (B,).  Rows
    ride the data axis exactly like every other per-slot array (same
    divisibility rule as ``batch_entry_for`` — B=1 admission shapes
    replicate), columns replicate.  Returns ``(tokens, per_row)`` specs;
    scalars (the chunk bound) use ``P()`` at the call site.
    """
    b = ctx.batch_entry_for(batch)
    return P(b, None), P(b)


def serve_snapshot_pspecs(ctx: ShardCtx, batch: int):
    """PartitionSpecs for a chunk snapshot — the packed host-facing output
    of ``serving.executor.Executor.chunk_snapshot_program`` that the
    overlap pipeline harvests one boundary late.  The (R, B) int row-pack
    shards its batch COLUMN on the data axis (rows enumerate
    ``executor.SNAP_ROWS``), the (B,) debiased-variance vector and the
    (B, T+1) token-buffer copy ride the data axis like every per-slot
    array — same ``batch_entry_for`` divisibility rule, so B=1 shapes
    replicate.  Keys mirror the snapshot dict of ``_snapshot_of``."""
    b = ctx.batch_entry_for(batch)
    return {"ints": P(None, b), "var": P(b), "tokens": P(b, None)}


def serve_state_pspecs(cfg: ModelConfig, ctx: ShardCtx, state):
    """PartitionSpec pytree for a ``serving.executor.ServeState``.

    The cache follows ``serving.cache.cache_pspecs`` (kv-heads / capacity /
    SSD-heads on the model axis; paged caches shard the page POOLS over the
    model axis and replicate the page table); every other field is a
    per-slot array with a leading batch dim that rides the data axis (when
    divisible — B=1 admission states stay replicated); the rng key is
    replicated.  This is the spec the executor feeds to ``jax.jit`` in/out
    shardings for its decode-chunk / admit / per-token programs.
    """
    # lazy: serving.cache imports ShardCtx from this module
    from repro.serving.cache import cache_pspecs

    if ctx.mesh is None:
        return jax.tree_util.tree_map(lambda _: P(), state)
    b = ctx.batch_entry_for(state.active.shape[0])

    def bspec(x):
        if getattr(x, "ndim", 0) == 0:
            return P()
        return P(b, *([None] * (x.ndim - 1)))

    specs = jax.tree_util.tree_map(bspec, state)
    return specs._replace(
        cache=cache_pspecs(cfg, ctx, state.cache),
        rng=P(),
    )
