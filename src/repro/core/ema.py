"""Exponential-moving-average mean/variance tracker (paper Eqs. 7-8 + the
de-biasing of Alg. 1 line 8).

    M_n = (1-a) M_{n-1} + a x_n
    V_n = (1-a) V_{n-1} + a (x_n - M_n)^2
    V'_n = V_n / (1 - (1-a)^n)          (initialization de-bias)

Pure-functional and vectorized over a batch of trackers (one per in-flight
sequence), so it runs inside jitted decode loops.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class EMAState(NamedTuple):
    mean: jax.Array     # (B,)
    var: jax.Array      # (B,)
    count: jax.Array    # (B,) int32 — updates seen


def ema_init(batch: int) -> EMAState:
    return EMAState(
        mean=jnp.zeros((batch,), jnp.float32),
        var=jnp.zeros((batch,), jnp.float32),
        count=jnp.zeros((batch,), jnp.int32),
    )


def ema_update(state: EMAState, x: jax.Array, alpha: float,
               active: jax.Array | None = None) -> EMAState:
    """One update per sequence; sequences with active=False are frozen."""
    m = (1.0 - alpha) * state.mean + alpha * x
    v = (1.0 - alpha) * state.var + alpha * (x - m) ** 2
    c = state.count + 1
    if active is not None:
        m = jnp.where(active, m, state.mean)
        v = jnp.where(active, v, state.var)
        c = jnp.where(active, c, state.count)
    return EMAState(mean=m, var=v, count=c)


def ema_debiased_var(state: EMAState, alpha: float) -> jax.Array:
    """V'_n = V_n / (1 - (1-a)^n); inf where no updates yet (never triggers
    a below-threshold stop before the first EAT evaluation)."""
    denom = 1.0 - (1.0 - alpha) ** jnp.maximum(state.count, 1).astype(jnp.float32)
    v = state.var / jnp.maximum(denom, 1e-12)
    return jnp.where(state.count > 0, v, jnp.inf)
