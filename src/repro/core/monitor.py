"""Reasoning-stream monitor: evaluation scheduling + stopper wiring.

The paper evaluates EAT every time the model emits a paragraph break
("\\n\\n" — one token in our synthetic tokenizer) and notes (App. G) that
every-S-tokens scheduling works equally well.  The monitor tracks, per
sequence, when an evaluation is *due*, feeds the stopper, and exposes the
combined exit decision.  It is jit-compatible: all state is arrays, all
decisions are masks — load-bearing now that the monitor transition runs
inside the engine's device-resident ``decode_chunk`` (a ``lax.while_loop``
body; see ``repro.serving.executor.make_eat_step``), not a host loop.
"""
from __future__ import annotations

import dataclasses
from typing import Literal, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.eat import ProbeSpec
from repro.core.stopping import EATState, EATStopper


class MonitorState(NamedTuple):
    stop_state: EATState
    since_eval: jax.Array      # (B,) tokens since last evaluation
    n_evals: jax.Array         # (B,) evaluations so far
    stop_flag: jax.Array       # (B,) bool latched exit decision


@dataclasses.dataclass(frozen=True)
class ReasoningMonitor:
    stopper: EATStopper
    probe: ProbeSpec
    schedule: Literal["newline", "every_n"] = "newline"
    newline_id: int = -1              # token id of "\n\n" (schedule=newline)
    every_n: int = 100                # schedule=every_n
    min_evals: int = 2                # don't stop before this many evals

    def init(self, batch: int) -> MonitorState:
        return MonitorState(
            stop_state=self.stopper.init(batch),
            since_eval=jnp.zeros((batch,), jnp.int32),
            n_evals=jnp.zeros((batch,), jnp.int32),
            stop_flag=jnp.zeros((batch,), bool),
        )

    def due(self, state: MonitorState, new_token: jax.Array) -> jax.Array:
        """(B,) — which sequences need an EAT evaluation after this token."""
        if self.schedule == "newline":
            return new_token == self.newline_id
        return (state.since_eval + 1) >= self.every_n

    def update(
        self,
        state: MonitorState,
        eat: jax.Array,           # (B,) EAT values (computed for all seqs)
        due: jax.Array,           # (B,) which seqs consume the evaluation
        active: jax.Array,        # (B,) still-reasoning mask
    ) -> MonitorState:
        use = due & active
        stop_state = self.stopper.update(state.stop_state, eat, active=use)
        n_evals = state.n_evals + use.astype(jnp.int32)
        since = jnp.where(use, 0, state.since_eval + active.astype(jnp.int32))
        should = self.stopper.should_stop(stop_state) & (n_evals >= self.min_evals)
        stop_flag = state.stop_flag | (should & active)
        return MonitorState(stop_state, since, n_evals, stop_flag)

    def tick_no_eval(self, state: MonitorState, active: jax.Array) -> MonitorState:
        return state._replace(
            since_eval=state.since_eval + active.astype(jnp.int32)
        )

    def observe(self, state: MonitorState, eat_fn, new_token: jax.Array,
                active: jax.Array, *, lazy: bool = True) -> MonitorState:
        """One decode step's full monitor transition, jit/scan-compatible.

        ``eat_fn() -> (B,)`` produces the EAT values (a probe forward —
        expensive).  With ``lazy=True`` it runs under ``lax.cond`` only when
        some active sequence hits an evaluation point, so steps between due
        points pay zero probe FLOPs; ``lazy=False`` probes unconditionally
        (the dry-run's every-token upper bound)."""
        due = self.due(state, new_token)
        if not lazy:
            return self.update(state, eat_fn(), due, active)
        return jax.lax.cond(
            (due & active).any(),
            lambda s: self.update(s, eat_fn(), due, active),
            lambda s: self.tick_no_eval(s, active),
            state,
        )
