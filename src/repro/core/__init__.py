# The paper's primary contribution: the EAT early-exit signal and its
# variance-threshold stopping rule, plus the baselines it is compared to.
from repro.core.eat import ProbeSpec, entropy_of_logits, eval_eat, make_probe  # noqa: F401
from repro.core.ema import EMAState, ema_debiased_var, ema_init, ema_update  # noqa: F401
from repro.core.monitor import MonitorState, ReasoningMonitor  # noqa: F401
from repro.core.stopping import (  # noqa: F401
    ConfidenceStopper,
    EATStopper,
    GiveUpStopper,
    TokenBudgetStopper,
    UniqueAnswerStopper,
    confidence_from_logprobs,
)
