"""EAT — Entropy After ``</think>`` (paper §4.1).

EAT = H( f(Q, <think>, r_1..r_n, </think> [, prefix]; phi) )       (Eq. 5/13)

where phi is the monitored model (the reasoning model itself in the
white-box setting, or a proxy in the black-box setting).  The probe is a
forward over the probe-token suffix against the live decode cache whose
returned cache is discarded (``Model.probe_entropy``); the entropy itself is
the fused ``entropy_probe`` kernel.

This module owns probe-token construction and the batched EAT evaluation
helper used by the serving engine and benchmarks.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.model import Model


@dataclasses.dataclass(frozen=True)
class ProbeSpec:
    """The token suffix appended (virtually) for an EAT evaluation.

    ``tokens[0]`` must be the stop-thinking token ``</think>``; the rest is
    the optional answer-inducing prefix (paper Eq. 13: "\\nThe final
    answer:"), which App. I.3 finds tightens the EAT <-> Pass@1 coupling for
    older models.  All probe tokens prefill in parallel against the existing
    cache, so the cost is ~one extra forward position regardless of length.
    """

    tokens: tuple[int, ...]

    def __len__(self) -> int:
        return len(self.tokens)


def make_probe(end_think_id: int, prefix_ids: Sequence[int] = ()) -> ProbeSpec:
    return ProbeSpec(tokens=(end_think_id, *prefix_ids))


def eval_eat(
    model: Model,
    params,
    cache,
    probe: ProbeSpec,
    next_pos: jax.Array,        # (B,) position the next real token would take
    *,
    entropy_impl: str = "auto",
    interpret: bool = False,
) -> jax.Array:
    """Batched EAT for every sequence sharing the cache.  (B,) float32.

    The probe tokens take positions next_pos + [0..m); the cache is NOT
    committed.
    """
    B = next_pos.shape[0]
    m = len(probe)
    toks = jnp.broadcast_to(jnp.asarray(probe.tokens, jnp.int32), (B, m))
    pos1d = next_pos[:, None] + jnp.arange(m, dtype=jnp.int32)[None, :]
    if model.cfg.mrope_sections:
        positions = jnp.broadcast_to(pos1d[..., None], (B, m, 3))
    else:
        positions = pos1d
    return model.probe_entropy(
        params, toks, positions, pos1d, cache,
        entropy_impl=entropy_impl, interpret=interpret,
    )


def entropy_of_logits(logits: jax.Array, vocab: int | None = None) -> jax.Array:
    """Reference entropy over (..., V) logits (Eq. 2), restricted to
    [:vocab] when the table is padded."""
    lf = logits.astype(jnp.float32)
    if vocab is not None and vocab < lf.shape[-1]:
        mask = jnp.arange(lf.shape[-1]) < vocab
        lf = jnp.where(mask, lf, -jnp.inf)
    logp = jax.nn.log_softmax(lf, axis=-1)
    p = jnp.exp(logp)
    return -jnp.where(p > 0, p * logp, 0.0).sum(-1)
