"""Early-exit stopping rules (paper Algs. 1-3 + the confidence baseline).

All stoppers share a functional interface usable inside jitted loops — a
hard requirement, not a convenience: ``EATStopper`` updates run inside the
engine's device-resident ``decode_chunk`` (``lax.while_loop`` body), so
state must be arrays and decisions masks, with no host round-trips:

    state  = stopper.init(batch)
    state  = stopper.update(state, signal, active)   # per evaluation point
    stop   = stopper.should_stop(state)              # (B,) bool

* ``EATStopper``        — Alg. 1: EMA variance of EAT below delta.
* ``TokenBudgetStopper``— Alg. 2: fixed per-question token limit T.
* ``UniqueAnswerStopper``— Alg. 3 (#UA@K): number of distinct answers among
  K forced rollouts <= Delta.  The rollouts themselves are produced by the
  engine (expensive — that is the paper's point, Fig. 6).
* ``ConfidenceStopper`` — Yang et al. 2025b (Eq. 16): EMA-var of the
  length-normalized likelihood of a greedy T'-token rollout.  We monitor it
  with the same EMA machinery; the engine supplies the confidence signal.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.ema import EMAState, ema_debiased_var, ema_init, ema_update


class EATState(NamedTuple):
    ema: EMAState
    last: jax.Array       # (B,) last signal value (for logging)


@dataclasses.dataclass(frozen=True)
class EATStopper:
    """Alg. 1: stop when the de-biased EMA variance of EAT < delta."""

    alpha: float = 0.2
    delta: float = 1e-3

    def init(self, batch: int) -> EATState:
        return EATState(ema=ema_init(batch), last=jnp.zeros((batch,), jnp.float32))

    def update(self, state: EATState, eat: jax.Array, active=None) -> EATState:
        ema = ema_update(state.ema, eat, self.alpha, active)
        last = eat if active is None else jnp.where(active, eat, state.last)
        return EATState(ema=ema, last=last)

    def debiased_var(self, state: EATState) -> jax.Array:
        return ema_debiased_var(state.ema, self.alpha)

    def should_stop(self, state: EATState) -> jax.Array:
        return self.debiased_var(state) < self.delta


@dataclasses.dataclass(frozen=True)
class TokenBudgetStopper:
    """Alg. 2: stop at a fixed reasoning-token budget T (plus natural
    ``</think>`` emission, which the engine checks regardless of stopper)."""

    budget: int = 10_000

    def init(self, batch: int):
        return jnp.zeros((batch,), jnp.int32)     # tokens generated

    def update(self, state, n_new_tokens: jax.Array, active=None):
        nxt = state + n_new_tokens
        return jnp.where(active, nxt, state) if active is not None else nxt

    def should_stop(self, state) -> jax.Array:
        return state >= self.budget


class UAState(NamedTuple):
    n_unique: jax.Array    # (B,) int32 — last measured #UA@K


@dataclasses.dataclass(frozen=True)
class UniqueAnswerStopper:
    """Alg. 3: stop when #unique answers among K rollouts <= Delta."""

    k: int = 16
    max_unique: int = 1

    def init(self, batch: int) -> UAState:
        return UAState(n_unique=jnp.full((batch,), 2**30, jnp.int32))

    def update(self, state: UAState, answers: jax.Array, active=None) -> UAState:
        """answers: (B, K) int32 canonical answer ids from K forced rollouts."""
        srt = jnp.sort(answers, axis=-1)
        uniq = 1 + (srt[:, 1:] != srt[:, :-1]).sum(-1)
        if active is not None:
            uniq = jnp.where(active, uniq, state.n_unique)
        return UAState(n_unique=uniq.astype(jnp.int32))

    def should_stop(self, state: UAState) -> jax.Array:
        return state.n_unique <= self.max_unique


@dataclasses.dataclass(frozen=True)
class ConfidenceStopper:
    """Yang et al. 2025b: confidence = exp(mean log p) over a greedy T'-token
    forced rollout (Eq. 16).  Stop when its EMA variance stabilizes (same
    rule shape as EAT so Fig. 4's comparison is apples-to-apples)."""

    alpha: float = 0.2
    delta: float = 1e-4
    rollout_len: int = 5

    def init(self, batch: int) -> EATState:
        return EATState(ema=ema_init(batch), last=jnp.zeros((batch,), jnp.float32))

    def update(self, state: EATState, confidence: jax.Array, active=None) -> EATState:
        ema = ema_update(state.ema, confidence, self.alpha, active)
        last = confidence if active is None else jnp.where(active, confidence, state.last)
        return EATState(ema=ema, last=last)

    def should_stop(self, state: EATState) -> jax.Array:
        return ema_debiased_var(state.ema, self.alpha) < self.delta


class GiveUpState(NamedTuple):
    ema: EMAState
    best_var: jax.Array        # (B,) lowest de-biased variance seen so far
    stall_streak: jax.Array    # (B,) consecutive non-improving high-var evals


@dataclasses.dataclass(frozen=True)
class GiveUpStopper:
    """BEYOND-PAPER (the paper's §6 'lower-threshold mechanism' future work):
    abandon reasoning when progress stalls.  On unsolvable questions (App.
    I.4) EAT never stabilizes and plain Alg. 1 burns the whole budget; here
    we track the best (lowest) de-biased variance reached so far and give up
    after ``patience`` consecutive evaluations that are BOTH above the
    stabilization ceiling AND fail to improve on the best by ``improve_tol``
    — the initial descent keeps setting new minima, so it never counts as a
    stall.  Compose with EATStopper: exit = stabilized OR gave up.
    """

    alpha: float = 0.2
    ceiling: float = 0.05
    patience: int = 8
    min_evals: int = 6
    improve_tol: float = 0.05      # relative improvement that resets the stall

    def init(self, batch: int) -> GiveUpState:
        return GiveUpState(
            ema=ema_init(batch),
            best_var=jnp.full((batch,), jnp.inf, jnp.float32),
            stall_streak=jnp.zeros((batch,), jnp.int32),
        )

    def update(self, state: GiveUpState, eat: jax.Array, active=None) -> GiveUpState:
        ema = ema_update(state.ema, eat, self.alpha, active)
        var = ema_debiased_var(ema, self.alpha)
        improving = var < state.best_var * (1.0 - self.improve_tol)
        stalled = (var > self.ceiling) & ~improving & (ema.count >= self.min_evals)
        streak = jnp.where(stalled, state.stall_streak + 1,
                           jnp.zeros_like(state.stall_streak))
        best = jnp.minimum(state.best_var, var)
        if active is not None:
            streak = jnp.where(active, streak, state.stall_streak)
            best = jnp.where(active, best, state.best_var)
        return GiveUpState(ema=ema, best_var=best, stall_streak=streak)

    def should_stop(self, state: GiveUpState) -> jax.Array:
        return state.stall_streak >= self.patience


def confidence_from_logprobs(logprobs: jax.Array, mask=None) -> jax.Array:
    """(B, T') per-token log p of a greedy rollout -> exp(mean)."""
    if mask is None:
        return jnp.exp(logprobs.mean(-1))
    s = (logprobs * mask).sum(-1) / jnp.maximum(mask.sum(-1), 1.0)
    return jnp.exp(s)
