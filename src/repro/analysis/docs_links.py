"""Pass 5 — docs link check (folded in from ``tools/check_docs_links.py``).

Every markdown inline link ``[text](target)`` in README.md and docs/*.md:

  * http(s)/mailto targets are skipped (no network in CI);
  * pure-anchor targets (``#section``) are skipped;
  * everything else must resolve to an existing file or directory relative
    to the file containing the link (``#anchor`` suffixes stripped first).

The old ``tools/check_docs_links.py`` CLI survives as a thin shim over
this module.
"""
from __future__ import annotations

import re
from pathlib import Path

from repro.analysis.common import PassResult, Violation

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")


def doc_files(repo: Path) -> list[Path]:
    files = [repo / "README.md"]
    files += sorted((repo / "docs").glob("*.md"))
    return [f for f in files if f.exists()]


def check(repo: Path, path: Path) -> tuple[list[Violation], int]:
    violations, n_links = [], 0
    text = path.read_text(encoding="utf-8")
    for lineno, line in enumerate(text.splitlines(), 1):
        for m in LINK_RE.finditer(line):
            target = m.group(1)
            n_links += 1
            if target.startswith(SKIP_PREFIXES):
                continue
            rel = target.split("#", 1)[0]
            if not rel:
                continue
            if not (path.parent / rel).resolve().exists():
                violations.append(Violation(
                    "docs", f"{path.relative_to(repo)}:{lineno}",
                    "broken-link", f"target does not exist: {target}"))
    return violations, n_links


def run(repo, files=None) -> PassResult:
    repo = Path(repo)
    files = list(files) if files is not None else doc_files(repo)
    violations: list[Violation] = []
    n_links = 0
    for f in files:
        v, n = check(repo, f)
        violations += v
        n_links += n
    return PassResult("docs", violations, {
        "files": len(files), "links": n_links,
    })
