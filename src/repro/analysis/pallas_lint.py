"""Pass 4 — Pallas kernel lint (docs/kernels.md invariants).

Three structural invariants every kernel wrapper in ``repro/kernels/*`` must
hold, checked over the AST without importing jax:

  index-map-closure  BlockSpec index maps must be pure functions of the
                     grid indices and scalar-prefetch refs (their lambda
                     parameters) plus *static* values — block sizes, head
                     ratios (``g = Hq // Hkv``), module constants.  A map
                     that closes over a traced array would silently bake
                     one trace's data into the block schedule.
  static-grid/block  ``grid=`` tuples and BlockSpec block shapes must be
                     built from static expressions (shapes, int-annotated
                     params, ``pl.cdiv`` of those) — a traced grid is a
                     recompile-per-step hazard and unmappable on TPU.
  where-mask         float fill values in ``jnp.where`` masking must be an
                     exact ``0.0`` (identity-step accumulators: masked
                     lanes contribute *bit-exact* zero, the property the
                     paged/ring equivalence tests rely on) or a -inf-like
                     constant (softmax masking, magnitude >= 1e20 so the
                     exp underflows to exactly 0).  ``-1e9``-style "large
                     enough" fills are flagged: they leave nonzero
                     probability mass and break bit-exactness.

Statics are inferred per wrapper function by fixpoint: int/bool-annotated
or int-defaulted params, ``.shape``/``.ndim``/``len()`` reads, module-level
constants/imports, and arithmetic/subscripts/``pl.cdiv`` over those.
"""
from __future__ import annotations

import ast
from pathlib import Path

from repro.analysis.common import PassResult, Violation
from repro.analysis.keys import free_names

_STATIC_CALLS = ("len", "int", "min", "max", "sum", "abs", "round", "divmod")
_NEG_INF_MIN = 1e20


def _module_statics(tree: ast.Module) -> set:
    """Top-level names: imports, constants, defs — all trace-independent."""
    out = set(dir(__builtins__)) if isinstance(__builtins__, dict) is False \
        else set(__builtins__)
    out |= {"True", "False", "None"}
    for node in tree.body:
        if isinstance(node, ast.Import):
            out |= {(a.asname or a.name).split(".")[0] for a in node.names}
        elif isinstance(node, ast.ImportFrom):
            out |= {a.asname or a.name for a in node.names}
        elif isinstance(node, ast.Assign):
            out |= {t.id for t in node.targets if isinstance(t, ast.Name)}
        elif isinstance(node, (ast.FunctionDef, ast.ClassDef)):
            out.add(node.name)
    return out


def _is_static(expr, static: set) -> bool:
    if isinstance(expr, ast.Constant):
        return True
    if isinstance(expr, ast.Name):
        return expr.id in static
    if isinstance(expr, ast.Attribute):
        if expr.attr in ("shape", "ndim", "dtype", "size"):
            return True
        return _is_static(expr.value, static)
    if isinstance(expr, (ast.Tuple, ast.List)):
        return all(_is_static(e, static) for e in expr.elts)
    if isinstance(expr, ast.BinOp):
        return _is_static(expr.left, static) and _is_static(expr.right, static)
    if isinstance(expr, ast.UnaryOp):
        return _is_static(expr.operand, static)
    if isinstance(expr, ast.BoolOp):
        return all(_is_static(v, static) for v in expr.values)
    if isinstance(expr, ast.Compare):
        return _is_static(expr.left, static) and \
            all(_is_static(c, static) for c in expr.comparators)
    if isinstance(expr, ast.Subscript):
        return _is_static(expr.value, static) and \
            _is_static(expr.slice, static)
    if isinstance(expr, ast.Slice):
        return all(s is None or _is_static(s, static)
                   for s in (expr.lower, expr.upper, expr.step))
    if isinstance(expr, ast.IfExp):
        return all(_is_static(e, static)
                   for e in (expr.test, expr.body, expr.orelse))
    if isinstance(expr, ast.Call):
        f = expr.func
        callable_ok = (isinstance(f, ast.Name) and f.id in _STATIC_CALLS) \
            or (isinstance(f, ast.Attribute) and _is_static(f.value, static))
        return callable_ok \
            and all(_is_static(a, static) for a in expr.args) \
            and all(_is_static(k.value, static) for k in expr.keywords)
    return False


def _fn_statics(fn: ast.FunctionDef, module_static: set) -> set:
    static = set(module_static)
    args = fn.args
    all_args = args.posonlyargs + args.args + args.kwonlyargs
    # params annotated int/bool, or defaulted to an int/bool literal
    defaults = [None] * (len(args.posonlyargs) + len(args.args)
                         - len(args.defaults)) + list(args.defaults)
    defaults += list(args.kw_defaults)
    for a, d in zip(all_args, defaults):
        ann_static = isinstance(a.annotation, ast.Name) \
            and a.annotation.id in ("int", "bool")
        dflt_static = isinstance(d, ast.Constant) \
            and isinstance(d.value, (int, bool)) \
            and not isinstance(d.value, float)
        if ann_static or dflt_static:
            static.add(a.arg)
    # fixpoint over assignments: statics propagate through unpacking
    changed = True
    while changed:
        changed = False
        for node in ast.walk(fn):
            if not isinstance(node, ast.Assign):
                continue
            if _is_static(node.value, static):
                for tgt in node.targets:
                    for nm in ast.walk(tgt):
                        if isinstance(nm, ast.Name) and nm.id not in static:
                            static.add(nm.id)
                            changed = True
    return static


def _lambda_default_names(lam: ast.Lambda) -> list:
    return [d for d in lam.args.defaults + [d for d in lam.args.kw_defaults
                                            if d is not None]]


def _check_block_spec(call, static, where, out):
    """One ``pl.BlockSpec(...)`` call: index-map lambda purity + static
    block shape (positional order varies across jax versions — classify by
    node type instead)."""
    operands = list(call.args) + [k.value for k in call.keywords]
    for op in operands:
        if isinstance(op, ast.Lambda):
            for name in sorted(free_names(op)):
                if name not in static:
                    out.append(Violation(
                        "pallas", f"{where}:{op.lineno}", "index-map-closure",
                        f"index map closes over non-static '{name}' — index "
                        f"maps must be pure functions of grid indices, "
                        f"scalar-prefetch refs and static sizes"))
            for d in _lambda_default_names(op):
                if not _is_static(d, static):
                    out.append(Violation(
                        "pallas", f"{where}:{op.lineno}", "index-map-closure",
                        "index-map lambda default is not a static "
                        "expression"))
        elif isinstance(op, (ast.Tuple, ast.List)):
            if not _is_static(op, static):
                out.append(Violation(
                    "pallas", f"{where}:{op.lineno}", "static-block",
                    "BlockSpec block shape contains a non-static element"))


def _check_fn(fn, module_static, fname, out) -> dict:
    static = _fn_statics(fn, module_static)
    counts = {"pallas_calls": 0, "index_maps": 0, "wheres": 0}
    for node in ast.walk(fn):
        if not isinstance(node, ast.Call):
            continue
        f = node.func
        callee = f.attr if isinstance(f, ast.Attribute) else \
            (f.id if isinstance(f, ast.Name) else "")
        where = f"{fname}:{node.lineno}"

        if callee in ("pallas_call", "PrefetchScalarGridSpec"):
            counts["pallas_calls"] += callee == "pallas_call"
            for kw in node.keywords:
                if kw.arg == "grid" and not _is_static(kw.value, static):
                    out.append(Violation(
                        "pallas", where, "static-grid",
                        "grid is not a static expression of shapes and "
                        "int params"))
        elif callee == "BlockSpec":
            counts["index_maps"] += any(
                isinstance(op, ast.Lambda)
                for op in list(node.args) + [k.value for k in node.keywords])
            _check_block_spec(node, static, fname, out)
        elif callee == "where":
            if len(node.args) == 3:
                counts["wheres"] += 1
                fill = node.args[2]
                bad = None
                if isinstance(fill, ast.Constant) \
                        and isinstance(fill.value, float) \
                        and fill.value != 0.0:
                    bad = fill.value
                elif isinstance(fill, ast.UnaryOp) \
                        and isinstance(fill.op, ast.USub) \
                        and isinstance(fill.operand, ast.Constant) \
                        and isinstance(fill.operand.value, (int, float)) \
                        and abs(fill.operand.value) < _NEG_INF_MIN:
                    bad = -fill.operand.value
                if bad is not None:
                    out.append(Violation(
                        "pallas", where, "where-mask",
                        f"masking fill {bad!r} is neither exact 0.0 nor a "
                        f"-inf-like constant (|x| >= {_NEG_INF_MIN:g}) — "
                        f"masked lanes must contribute bit-exact zero"))
    return counts


def run(paths) -> PassResult:
    violations: list[Violation] = []
    stats = {"files": 0, "pallas_calls": 0, "index_maps": 0, "wheres": 0}
    for path in paths:
        path = Path(path)
        stats["files"] += 1
        tree = ast.parse(path.read_text(encoding="utf-8"))
        module_static = _module_statics(tree)
        rel = "/".join(path.parts[-3:])
        for node in ast.walk(tree):
            if isinstance(node, ast.FunctionDef):
                c = _check_fn(node, module_static, rel, violations)
                for k, v in c.items():
                    stats[k] += v
    return PassResult("pallas", violations, stats)
