"""repro-audit: static contract analyzer for the serving stack.

Five passes, one runner (``python -m tools.audit.run``; docs/analysis.md):

  layering  import-graph contracts: scheduler/request stay pure-host,
            executor.py is the only jit-builder in serving/, kernels never
            import serving, deleted shims stay deleted
  keys      program-key completeness: config read by a builder closure =>
            present in that program's cache key (executor.KEY_EXEMPT waives)
  pallas    kernel lint: static grids/BlockSpecs, index maps free of traced
            closures, exact-zero/neg-inf where-masking (the identity-step pin)
  docs      no broken relative links in README.md / docs/*.md
  lowered   lower every executor/ProxyExecutor program over the full key
            matrix; scan jaxprs for forbidden ops; audit the donation
            contract in the compiled artifacts

Each pass returns a ``PassResult`` (``repro.analysis.common``); the passes
themselves live in sibling modules so tests can point them at fixture trees.
"""
from __future__ import annotations

from repro.analysis.common import PassResult, Violation

__all__ = ["PassResult", "Violation", "run_passes", "PASS_NAMES"]

PASS_NAMES = ("layering", "keys", "pallas", "docs", "lowered")


def run_passes(names, repo, quick: bool = False) -> list[PassResult]:
    """Run the selected passes over the real tree rooted at ``repo``.

    ``lowered`` is imported lazily — it pulls in jax and traces programs;
    the other four are pure-AST/filesystem and stay cheap.
    """
    from pathlib import Path

    repo = Path(repo)
    results = []
    for name in names:
        if name == "layering":
            from repro.analysis import layering

            results.append(layering.run(repo / "src"))
        elif name == "keys":
            from repro.analysis import keys

            results.append(keys.run(repo / "src/repro/serving/executor.py"))
        elif name == "pallas":
            from repro.analysis import pallas_lint

            results.append(pallas_lint.run(
                sorted((repo / "src/repro/kernels").glob("*/kernel.py"))))
        elif name == "docs":
            from repro.analysis import docs_links

            results.append(docs_links.run(repo))
        elif name == "lowered":
            from repro.analysis import lowered

            results.append(lowered.run(quick=quick))
        else:
            raise ValueError(f"unknown pass {name!r} (choose from "
                             f"{', '.join(PASS_NAMES)})")
    return results
