"""Pass 1 — import-layering (docs/architecture.md §Layer contracts).

Pure-AST checks over the import graph and jit-construction sites:

  * ``pure_host`` modules (scheduler, request — and with them the
    ``PageAllocator``) never import jax: every scheduling decision stays a
    host list/numpy operation, unit-testable without a device;
  * within the ``jit_scope`` package (serving/), only the ``jit_owner``
    module (executor.py) constructs jitted programs — ``jax.jit`` /
    ``pjit`` references anywhere else are flagged (this is how the
    ProxyMonitor jit sites were caught and moved in this PR);
  * ``kernel_pkg`` modules never import from ``app_pkg`` (kernels are
    leaves; a kernel reaching up into serving/ would invert the stack);
  * ``dispatch_only`` modules (serving/pipeline — the overlapped serve
    loop) never reference a blocking primitive (``jax.block_until_ready``,
    ``device_get``): the pipeline's whole point is that the only blocking
    read is ``np.asarray`` on a chunk snapshot, one boundary behind the
    dispatch frontier — a stray sync there silently re-serializes serving;
  * ``banned_paths`` stay deleted (the ``launch/serve_step.py`` shim).

Rules are data so tests can run the pass over fixture trees.
"""
from __future__ import annotations

import ast
from pathlib import Path

from repro.analysis.common import PassResult, Violation

DEFAULT_RULES = {
    "pure_host": ("repro.serving.scheduler", "repro.serving.request"),
    "pure_host_forbidden": ("jax", "jaxlib"),
    "jit_owner": "repro.serving.executor",
    "jit_scope": "repro.serving",
    "kernel_pkg": "repro.kernels",
    "app_pkg": "repro.serving",
    "dispatch_only": ("repro.serving.pipeline",),
    "dispatch_only_forbidden": ("block_until_ready", "device_get"),
    "banned_paths": ("repro/launch/serve_step.py",),
}


def module_name(src_root: Path, path: Path) -> str:
    rel = path.relative_to(src_root).with_suffix("")
    parts = list(rel.parts)
    if parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts)


def imports_of(tree: ast.Module, modname: str) -> list[tuple[str, int]]:
    """All imported module names (absolute, relative resolved), with lines."""
    out = []
    pkg_parts = modname.split(".")[:-1]
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            out += [(a.name, node.lineno) for a in node.names]
        elif isinstance(node, ast.ImportFrom):
            if node.level:
                base = pkg_parts[:len(pkg_parts) - node.level + 1]
                mod = ".".join(base + ([node.module] if node.module else []))
            else:
                mod = node.module or ""
            out.append((mod, node.lineno))
            # ``from pkg import sub`` may bind submodules; record those too
            out += [(f"{mod}.{a.name}", node.lineno) for a in node.names]
    return out


def jit_sites(tree: ast.Module) -> list[int]:
    """Lines referencing ``jax.jit`` / ``pjit`` — any load, not just calls,
    so aliasing (``jit = jax.jit``) and ``functools.partial(jax.jit, ...)``
    are caught as well."""
    lines = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Attribute) and node.attr in ("jit", "pjit"):
            base = node.value
            if isinstance(base, ast.Name) and base.id == "jax":
                lines.append(node.lineno)
            elif isinstance(base, ast.Attribute):        # jax.experimental.pjit
                lines.append(node.lineno)
        elif isinstance(node, ast.Name) and node.id == "pjit":
            lines.append(node.lineno)
    return lines


def blocking_sites(tree: ast.Module, forbidden: tuple) -> list[tuple[str, int]]:
    """Lines referencing a blocking primitive — attribute loads
    (``jax.block_until_ready``, ``dev.device_get``) and bare names
    (``from jax import block_until_ready``) both count, so aliasing
    cannot hide a sync."""
    sites = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Attribute) and node.attr in forbidden:
            sites.append((node.attr, node.lineno))
        elif isinstance(node, ast.Name) and node.id in forbidden:
            sites.append((node.id, node.lineno))
        elif isinstance(node, ast.ImportFrom):
            sites += [(a.name, node.lineno) for a in node.names
                      if a.name in forbidden]
    return sites


def _imports_root(name: str, roots: tuple) -> bool:
    return any(name == r or name.startswith(r + ".") for r in roots)


def run(src_root, rules: dict | None = None) -> PassResult:
    src_root = Path(src_root)
    rules = {**DEFAULT_RULES, **(rules or {})}
    violations: list[Violation] = []
    n_modules = 0

    for path in sorted(src_root.rglob("*.py")):
        mod = module_name(src_root, path)
        if not mod:
            continue
        n_modules += 1
        tree = ast.parse(path.read_text(encoding="utf-8"))
        imps = imports_of(tree, mod)

        if mod in rules["pure_host"]:
            for name, line in imps:
                if _imports_root(name, tuple(rules["pure_host_forbidden"])):
                    violations.append(Violation(
                        "layering", f"{mod}:{line}", "pure-host",
                        f"pure-host module imports {name} — scheduling "
                        f"decisions must stay device-free"))

        scope = rules["jit_scope"]
        if (mod == scope or mod.startswith(scope + ".")) \
                and mod != rules["jit_owner"]:
            for line in jit_sites(tree):
                violations.append(Violation(
                    "layering", f"{mod}:{line}", "executor-only-jit",
                    f"jit program construction outside {rules['jit_owner']} "
                    f"— all serving programs are built by the executor"))

        if mod in rules["dispatch_only"]:
            for name, line in blocking_sites(
                    tree, tuple(rules["dispatch_only_forbidden"])):
                violations.append(Violation(
                    "layering", f"{mod}:{line}", "dispatch-only",
                    f"dispatch-only module references blocking primitive "
                    f"'{name}' — the overlapped serve loop may only block "
                    f"through np.asarray on a chunk snapshot"))

        kpkg = rules["kernel_pkg"]
        if mod == kpkg or mod.startswith(kpkg + "."):
            for name, line in imps:
                if _imports_root(name, (rules["app_pkg"],)):
                    violations.append(Violation(
                        "layering", f"{mod}:{line}", "kernels-are-leaves",
                        f"kernel module imports {name} — kernels must not "
                        f"depend on the serving stack"))

    for banned in rules["banned_paths"]:
        if (src_root / banned).exists():
            violations.append(Violation(
                "layering", banned, "stays-deleted",
                "deprecated shim has been reintroduced"))

    return PassResult("layering", violations, {
        "modules": n_modules,
        "rules": 5,
        "pure_host": list(rules["pure_host"]),
        "jit_owner": rules["jit_owner"],
        "dispatch_only": list(rules["dispatch_only"]),
    })
