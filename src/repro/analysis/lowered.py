"""Pass 2 — lowered-program audit: trace/lower every serving program and
check the artifacts, not the source.

The other passes read the AST; this one asks jax.  Every executor /
ProxyExecutor program family (``executor.PROGRAM_FAMILIES``) is built over
the full key matrix

    monitor tier {self, proxy} x cache kind {ring, paged}
    x decode-attention impl {gather, xla, pallas(interpret on CPU)}
    x delta regime {exit-at-first-eval, run-to-budget} for the monitored
      families (chunk / shadow / serve_step — delta is a traced constant)

using ``jax.eval_shape`` structs only (no device arrays, no model init:
auditing is shape-level).  Three artifact checks per program:

  sync-point       the jaxpr (recursively, through cond/while/scan
                   branches) and the lowered StableHLO must contain no
                   host callbacks (``pure_callback`` / ``io_callback`` /
                   ``debug_callback``) and no infeed/outfeed — a callback
                   inside a decode chunk serializes every dispatch on the
                   host;
  float-widening   no ``convert_element_type`` that widens a non-scalar
                   float array (a silent fp32 upcast of a bf16 cache
                   doubles the serving footprint);
  donation         ``DONATION_CONTRACT``: compiled programs of donating
                   families must alias input to output buffers
                   (``memory_analysis().alias_size_in_bytes > 0`` — the KV
                   cache is updated in place), and the deliberately
                   functional families (decode / probe / rollout) must
                   alias nothing.  Compiling is the expensive step, so the
                   contract is checked once per family in designated cells
                   (donation is impl-independent); every other cell stops
                   at trace + lower.

The proxy tier additionally gets the black-box assertion from
docs/architecture.md: after building a proxy cell, the GENERATOR executor's
program store must contain no probe program and no monitored chunk — no
generator logits feed the exit decision.

``launch.dryrun`` imports ``scan_hlo_text`` from here (lazily, inside
``run_one``) so the roofline artifacts get the same sync-point screen.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.analysis.common import PassResult, Violation

# audit-sized serving geometry: tiny model, 2 rows, 4 blocks of 8 slots
B = 2
CAP = 32
PAGE = 8
NB = CAP // PAGE
NUM_PAGES = B * NB + 1          # ring-equivalent pool + trash page
C_PRE = 16                      # dense prefill capacity (page multiple)
T_BUF = 16                      # out_tokens buffer / shadow stream width
PROMPT = 8

TIERS = ("self", "proxy")
KINDS = ("ring", "paged")
IMPLS = ("gather", "xla", "pallas")
REGIMES = (("exit", 1e-3), ("never", 1e9))

#: (tier, kind) cells in which each family's donation contract is compiled
#: and checked — once per family, on the gather impl (donation is a buffer
#: aliasing property of the jit call, not of the attention algorithm).
_DONATION_CELLS = {
    ("self", "ring"): ("chunk", "chunk_snapshot", "decode", "prefill",
                       "probe", "admit", "rollout", "serve_step"),
    ("self", "paged"): ("pack", "admit"),
    ("proxy", "ring"): ("shadow", "retract", "retract_lagged"),
}


def _i32(shape=()):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


# ------------------------------------------------------------------- scans
def _subjaxprs(value):
    if hasattr(value, "jaxpr"):
        yield value.jaxpr
    elif hasattr(value, "eqns"):
        yield value
    elif isinstance(value, (list, tuple)):
        for v in value:
            yield from _subjaxprs(v)


def scan_jaxpr(jaxpr, where: str) -> list:
    """Sync-point + float-widening screen over a (closed) jaxpr, recursing
    through control-flow sub-jaxprs."""
    out = []
    seen = set()

    def walk(jx):
        if id(jx) in seen:
            return
        seen.add(id(jx))
        for eqn in jx.eqns:
            name = eqn.primitive.name
            if "callback" in name or name in ("infeed", "outfeed"):
                out.append(Violation(
                    "lowered", where, "sync-point",
                    f"jaxpr contains host sync primitive '{name}' — a "
                    f"callback inside a serving program serializes every "
                    f"dispatch on the host"))
            elif name == "convert_element_type":
                old = eqn.invars[0].aval
                new = eqn.outvars[0].aval
                if (getattr(old, "ndim", 0) >= 2
                        and jnp.issubdtype(old.dtype, jnp.floating)
                        and jnp.issubdtype(new.dtype, jnp.floating)
                        and new.dtype.itemsize > old.dtype.itemsize):
                    out.append(Violation(
                        "lowered", where, "float-widening",
                        f"non-scalar float widening "
                        f"{old.dtype.name}->{new.dtype.name} on shape "
                        f"{tuple(old.shape)} — silent upcasts multiply the "
                        f"serving footprint"))
            for v in eqn.params.values():
                for sub in _subjaxprs(v):
                    walk(sub)

    walk(jaxpr.jaxpr if hasattr(jaxpr, "jaxpr") else jaxpr)
    return out


def scan_hlo_text(text: str, where: str = "hlo") -> list:
    """Sync-point screen over lowered StableHLO/HLO text (belt to the
    jaxpr's braces: callbacks that lower to custom calls keep 'callback'
    in the target name)."""
    out = []
    for marker in ("callback", "infeed", "outfeed"):
        if marker in text:
            out.append(Violation(
                "lowered", where, "sync-point",
                f"lowered program text contains '{marker}'"))
    return out


def check_donation(compiled, family: str, donate: bool, where: str) -> list:
    """``DONATION_CONTRACT`` against the compiled artifact's aliasing."""
    mem = compiled.memory_analysis()
    if mem is None or not hasattr(mem, "alias_size_in_bytes"):
        return []
    alias = int(mem.alias_size_in_bytes)
    if donate and alias <= 0:
        return [Violation(
            "lowered", where, "donation",
            f"family '{family}' must donate (update the cache in place) "
            f"but the compiled program aliases 0 bytes")]
    if not donate and alias > 0:
        return [Violation(
            "lowered", where, "donation",
            f"family '{family}' is contractually functional but the "
            f"compiled program aliases {alias} bytes of its inputs")]
    return []


# ------------------------------------------------------------- cell set-up
def _monitor(delta: float):
    from repro.core.eat import make_probe
    from repro.core.monitor import ReasoningMonitor
    from repro.core.stopping import EATStopper

    return ReasoningMonitor(
        stopper=EATStopper(alpha=0.2, delta=delta),
        probe=make_probe(1, (4,)),
        schedule="every_n", every_n=4, min_evals=1,
    )


def _ecfg(kind: str, impl: str):
    from repro.serving.cache import CacheConfig
    from repro.serving.engine import EngineConfig
    from repro.serving.sampler import SamplerConfig

    return EngineConfig(
        max_reasoning_tokens=T_BUF, capacity=CAP, chunk_len=8,
        sampler=SamplerConfig(greedy=True),
        cache=CacheConfig(kind=kind, page_size=PAGE, num_pages=NUM_PAGES,
                          attn_impl=impl if kind == "paged" else "gather"),
    )


def _model(name: str, impl: str):
    from repro.configs.base import get_config
    from repro.models import Model

    model = Model(get_config(name), attn_impl="xla",
                  paged_attn_impl=impl, paged_attn_page=PAGE)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    return model, params


def _cache_struct(cfg, kind: str, impl: str, batch: int):
    from repro.serving.cache import alloc_cache, alloc_paged_template
    from repro.serving.scheduler import PageAllocator

    def mk():
        if kind == "ring":
            return alloc_cache(cfg, batch, CAP)
        native = impl != "gather"
        alloc = PageAllocator(NUM_PAGES, PAGE, NB, batch) if native else None
        return alloc_paged_template(cfg, batch, CAP, PAGE, NUM_PAGES,
                                    alloc=alloc, native=native)

    return jax.eval_shape(mk)


def _dense_struct(cfg, batch: int, capacity: int):
    from repro.serving.cache import alloc_cache

    return jax.eval_shape(lambda: alloc_cache(cfg, batch, capacity))


def _state_struct(cfg, monitor, cache_struct, batch: int):
    from repro.serving.executor import ServeState

    def mk():
        return ServeState(
            cache=jax.tree_util.tree_map(
                lambda s: jnp.zeros(s.shape, s.dtype), cache_struct),
            rng=jax.random.PRNGKey(0),
            active=jnp.ones((batch,), bool),
            next_pos=jnp.zeros((batch,), jnp.int32),
            last_token=jnp.zeros((batch,), jnp.int32),
            n_reasoning=jnp.zeros((batch,), jnp.int32),
            monitor=monitor.init(batch),
            ended_think=jnp.zeros((batch,), bool),
            out_tokens=jnp.zeros((batch, T_BUF), jnp.int32),
            out_len=jnp.zeros((batch,), jnp.int32),
        )

    return jax.eval_shape(mk)


def _rng_struct():
    return jax.eval_shape(lambda: jax.random.PRNGKey(0))


# --------------------------------------------------------------- the audit
class _Audit:
    def __init__(self):
        self.violations: list[Violation] = []
        self.keys: set = set()
        self.n_lowered = 0
        self.n_donation_checked = 0
        self.families: set = set()

    def program(self, tag: tuple, family: str, prog, args, *,
                donate: bool | None = None, compile_donation: bool = False):
        """tag = (tier, kind, impl, regime, program-key)."""
        where = "/".join(str(t) for t in tag)
        try:
            jaxpr = prog.trace(*args).jaxpr
            self.violations += scan_jaxpr(jaxpr, where)
            lowered = prog.lower(*args)
            self.violations += scan_hlo_text(lowered.as_text(), where)
            self.keys.add(tag)
            self.families.add(family)
            self.n_lowered += 1
            if compile_donation and donate is not None:
                self.violations += check_donation(
                    lowered.compile(), family, donate, where)
                self.n_donation_checked += 1
        except Exception as e:  # surface, don't abort the whole audit
            self.violations.append(Violation(
                "lowered", where, "lowering-failed",
                f"{type(e).__name__}: {e}"))


def _audit_self_cell(a: _Audit, kind: str, impl: str):
    from repro.serving.executor import (
        DONATION_CONTRACT,
        Executor,
        ServeStepConfig,
        build_serve_step_program,
    )
    from repro.serving.sampler import SamplerConfig

    model, params = _model("tiny", impl)
    cfg = model.cfg
    ecfg = _ecfg(kind, impl)
    don_fams = _DONATION_CELLS.get(("self", kind), ()) if impl == "gather" \
        else ()

    def dc(family):
        return family in don_fams

    s0 = _i32()
    for regime, delta in REGIMES:
        monitor = _monitor(delta)
        ex = Executor(model, params, ecfg, monitor)
        cache = _cache_struct(cfg, kind, impl, B)
        state = _state_struct(cfg, monitor, cache, B)

        def tag(prog_key, rg=regime):
            return ("self", kind, impl, rg, str(prog_key))

        # monitored chunk: the delta regime is traced into the stop rule
        prog = ex.chunk_program(state, True)
        a.program(tag(("chunk", B, True, True)), "chunk", prog,
                  (params, state, s0, s0), donate=True,
                  compile_donation=dc("chunk") and regime == "exit")
        # overlap-mode variant: the chunk plus its packed host snapshot —
        # same delta sensitivity, and the snapshot outputs must NOT break
        # the state donation (the pipeline reads them after the state has
        # been donated into the next dispatch)
        a.program(tag(("chunk", B, True, True, "snap")), "chunk_snapshot",
                  ex.chunk_snapshot_program(state, True),
                  (params, state, s0, s0), donate=True,
                  compile_donation=dc("chunk_snapshot") and regime == "exit")

        if regime != "exit":
            continue           # the remaining programs don't read delta

        a.program(tag(("chunk", B, False, True)), "chunk",
                  ex.chunk_program(state, False), (params, state, s0, s0))
        a.program(tag(("decode", B)), "decode", ex.decode_program(state),
                  (params, state),
                  donate=DONATION_CONTRACT["decode"] is not None,
                  compile_donation=dc("decode"))
        a.program(tag(("probe", B)), "probe", ex.probe_program(cache, B),
                  (params, cache, _i32((B,))),
                  donate=DONATION_CONTRACT["probe"] is not None,
                  compile_donation=dc("probe"))
        a.program(tag(("rollout", B, 4, True)), "rollout",
                  ex.rollout_program(cache, B, 4, True),
                  (params, cache, _i32((B,)), _i32((B,)), _rng_struct()),
                  donate=DONATION_CONTRACT["rollout"] is not None,
                  compile_donation=dc("rollout"))

        dense = _dense_struct(cfg, B, C_PRE)
        a.program(tag(("prefill", B)), "prefill",
                  ex.prefill_program(dense, B),
                  (params, _i32((B, PROMPT)), _i32((B, PROMPT)),
                   _i32((B, PROMPT)), dense),
                  donate=True, compile_donation=dc("prefill"))

        if kind == "ring":
            one = _state_struct(cfg, monitor, _cache_struct(cfg, kind, impl, 1), 1)
            a.program(tag(("admit", B)), "admit", ex.admit_program(state, one),
                      (state, one, s0), donate=True,
                      compile_donation=dc("admit"))
        else:
            a.program(tag(("pack", B, C_PRE)), "pack",
                      ex.pack_paged_program(cache, dense),
                      (cache, dense, _i32((B, NB))),
                      donate=True, compile_donation=dc("pack"))
            one = _state_struct(cfg, monitor, _dense_struct(cfg, 1, C_PRE), 1)
            a.program(tag(("admit", B, "paged", C_PRE)), "admit",
                      ex.admit_paged_program(state, one),
                      (state, one, s0, _i32((NB,))),
                      donate=True, compile_donation=dc("admit"))

    # the dry-run's every-token step, both regimes — the exact program
    # launch.dryrun lowers and costs out (gather cells only: the regime
    # coverage is about the stop rule, not the attention impl)
    if impl == "gather":
        for regime, delta in REGIMES:
            from repro.core.stopping import EATStopper

            monitor = _monitor(delta)
            cache = _cache_struct(cfg, kind, impl, B)
            scfg = ServeStepConfig(
                probe=monitor.probe,
                stopper=EATStopper(alpha=0.2, delta=delta),
                sampler=SamplerConfig(greedy=True),
            )
            jitted, mon_struct = build_serve_step_program(
                model, scfg, cache, params)
            a.program(("self", kind, impl, regime, str(("serve_step", B))),
                      "serve_step", jitted,
                      (params, cache, _i32((B, 1)), _i32((B, 1)),
                       mon_struct, _rng_struct()),
                      donate=True,
                      compile_donation="serve_step" in
                      _DONATION_CELLS.get(("self", kind), ())
                      and regime == "exit")


def _audit_proxy_cell(a: _Audit, kind: str, impl: str):
    """Proxy tier: the generator decodes blind (no probe, no monitored
    chunk) and the ProxyExecutor shadows its emitted chunks."""
    from repro.serving.executor import (
        DONATION_CONTRACT,
        Executor,
        ProxyExecutor,
        build_stream_monitor_programs,
    )

    gmodel, gparams = _model("tiny", impl)
    pmodel, pparams = _model("tiny-proxy", impl)
    ecfg = _ecfg(kind, impl)
    don_fams = _DONATION_CELLS.get(("proxy", kind), ()) if impl == "gather" \
        else ()
    s0 = _i32()

    # generator side: monitor is inert in proxy mode (use_monitor=False)
    gen_monitor = _monitor(1e9)
    gex = Executor(gmodel, gparams, ecfg, gen_monitor)
    gcache = _cache_struct(gmodel.cfg, kind, impl, B)
    gstate = _state_struct(gmodel.cfg, gen_monitor, gcache, B)

    a.program(("proxy", kind, impl, "never", str(("chunk", B, False, True))),
              "chunk", gex.chunk_program(gstate, False),
              (gparams, gstate, s0, s0))
    a.program(("proxy", kind, impl, "never", str(("retract", B))),
              "retract", gex.retract_program(gstate),
              (gstate, _i32((B,)), jax.eval_shape(
                  lambda: gen_monitor.init(B))),
              donate=DONATION_CONTRACT["retract"] is not None,
              compile_donation="retract" in don_fams)
    # overlap-mode programs on the generator chain: the snapshot chunk the
    # pipeline dispatches ahead, and the one-boundary-late reconciliation
    a.program(("proxy", kind, impl, "never",
               str(("chunk", B, False, True, "snap"))),
              "chunk_snapshot", gex.chunk_snapshot_program(gstate, False),
              (gparams, gstate, s0, s0))
    a.program(("proxy", kind, impl, "never", str(("retract", B, "lagged"))),
              "retract_lagged", gex.retract_lagged_program(gstate),
              (gstate, _i32((B,)), jax.eval_shape(
                  lambda: gen_monitor.init(B))),
              donate=DONATION_CONTRACT["retract"] is not None,
              compile_donation="retract_lagged" in don_fams)

    # the black-box contract, checked on the artifacts: the generator
    # program store must hold no probe and no monitored chunk
    for key in gex._programs:
        if key[0] == "probe" or (key[0] == "chunk" and key[2]):
            a.violations.append(Violation(
                "lowered", f"proxy/{kind}/{impl}", "black-box",
                f"generator executor built {key} in proxy mode — generator "
                f"logits must not feed the exit decision"))

    # proxy side: shadow decode over both delta regimes
    for regime, delta in REGIMES:
        monitor = _monitor(delta)
        px = ProxyExecutor(pmodel, pparams, ecfg, monitor)
        pcache = _cache_struct(pmodel.cfg, kind, impl, B)
        pstate = _state_struct(pmodel.cfg, monitor, pcache, B)
        a.program(("proxy", kind, impl, regime, str(("shadow", B, T_BUF))),
                  "shadow", px.observe_chunk_program(pstate, T_BUF),
                  (pparams, pstate, _i32((B, T_BUF)), _i32((B,)),
                   _i32((B,)), s0),
                  donate=True,
                  compile_donation="shadow" in don_fams
                  and regime == "exit")

    # the host-streaming ProxyMonitor's programs (built by the executor
    # module for proxy.py — the layering fix this PR) — once is enough
    if kind == "ring" and impl == "gather":
        consume, probe_fn, _prefill = build_stream_monitor_programs(
            pmodel, _monitor(1e-3).probe)
        dense = _dense_struct(pmodel.cfg, B, CAP)
        a.program(("proxy", kind, impl, "exit", "('stream_consume',)"),
                  "stream", consume,
                  (pparams, dense, _i32((B, PROMPT)), _i32((B,))))
        a.program(("proxy", kind, impl, "exit", "('stream_probe',)"),
                  "stream", probe_fn, (pparams, dense, _i32((B,))))


def run(quick: bool = False) -> PassResult:
    a = _Audit()
    cells = [(t, k, i) for t in TIERS for k in KINDS for i in IMPLS]
    if quick:
        cells = [("self", "ring", "gather"), ("proxy", "paged", "xla")]
    for tier, kind, impl in cells:
        if tier == "self":
            _audit_self_cell(a, kind, impl)
        else:
            _audit_proxy_cell(a, kind, impl)

    covered = {(t, k, i) for (t, k, i, _, _) in a.keys}
    return PassResult("lowered", a.violations, {
        "cells": len(cells),
        "cells_covered": len(covered),
        "programs_lowered": a.n_lowered,
        "distinct_keys": len(a.keys),
        "donation_checked": a.n_donation_checked,
        "families": sorted(a.families),
        "quick": quick,
    })
