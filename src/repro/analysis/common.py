"""Shared result types for the analysis passes (docs/analysis.md)."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Violation:
    """One contract breach: where it is, which rule, and what happened."""

    pass_name: str
    where: str          # file:line, module name, or program key
    rule: str           # short machine-stable rule id, e.g. "pure-host"
    detail: str         # human explanation

    def __str__(self) -> str:
        return f"[{self.pass_name}/{self.rule}] {self.where}: {self.detail}"


@dataclasses.dataclass
class PassResult:
    """Outcome of one pass: violations plus the coverage it can attest to."""

    name: str
    violations: list
    stats: dict = dataclasses.field(default_factory=dict)

    @property
    def ok(self) -> bool:
        return not self.violations

    def to_json(self) -> dict:
        return {
            "name": self.name,
            "ok": self.ok,
            "stats": self.stats,
            "violations": [dataclasses.asdict(v) for v in self.violations],
        }
