"""Pass 3 — program-key completeness lint (the stale-program hazard).

Executor program builders follow one shape::

    def family_program(self, state, knob):
        key = ("family", ..., knob, self._kind(state.cache))
        if key not in self._programs:
            def fn(...):
                ... closes over knob / self attributes ...
            self._programs[key] = jax.jit(fn, donate_argnums=...)
        return self._programs[key]

``jax.jit`` retraces automatically on shape/pytree changes, so the ONLY
silent-staleness vector is a *static Python value baked into the closure*
(or into the jit call itself, e.g. ``donate_argnums``) that is not part of
``key``: two calls with different knob values would then be served the same
cached program.  This is exactly the hazard class the ``attn_impl`` knob of
PR 5 had to plumb by hand through every key (``Executor._kind``).

The lint finds every builder (a method that assigns a ``key`` tuple and
stores into ``self._programs[key]``) and checks, per builder:

  key-param   a method parameter read (transitively) by the jitted
              closure, or by a non-sharding ``jax.jit`` argument, must
              appear in the key tuple;
  key-shape   a local derived from a ``.shape`` / ``len()`` read that the
              closure captures must appear in the key tuple;
  key-kind    a closure that reaches instance state (``self.*`` — in
              particular the model and its decode-attention impl) while
              the builder takes a cache/state template must carry
              ``self._kind(...)`` in its key.

Names rooted at ``self`` are otherwise allowed: an ``Executor`` is
immutable per (model, EngineConfig, monitor) by contract.  ``in_shardings``
/ ``out_shardings`` are excluded: sharding trees depend only on pytree
structure, and a structure mismatch fails loudly at dispatch instead of
serving a stale program.  A family listed in the module's ``KEY_EXEMPT``
dict literal is waived (the waiver text is the justification — see
``serving/executor.py``).
"""
from __future__ import annotations

import ast
from pathlib import Path

from repro.analysis.common import PassResult, Violation

_SHARDING_KWARGS = ("in_shardings", "out_shardings")
_SHAPE_ATTRS = ("shape", "ndim", "dtype")


def _params_of(fn) -> list[str]:
    a = fn.args
    names = [x.arg for x in a.posonlyargs + a.args + a.kwonlyargs]
    if a.vararg:
        names.append(a.vararg.arg)
    if a.kwarg:
        names.append(a.kwarg.arg)
    return names


def free_names(fn) -> set:
    """Free variable names of a function/lambda: loads not bound by its
    params or local assignments, including frees of nested defs.  Default
    expressions of nested functions evaluate in THIS scope and count."""
    bound = set(_params_of(fn))
    assigned, loads, nested = set(), set(), []

    def visit(node):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            assigned.add(node.name)
            nested.append(node)
            for d in node.args.defaults + [d for d in node.args.kw_defaults
                                           if d is not None]:
                visit(d)
            return
        if isinstance(node, ast.Lambda):
            nested.append(node)
            for d in node.args.defaults + [d for d in node.args.kw_defaults
                                           if d is not None]:
                visit(d)
            return
        if isinstance(node, ast.Name):
            (loads if isinstance(node.ctx, ast.Load) else assigned).add(node.id)
        for child in ast.iter_child_nodes(node):
            visit(child)

    body = fn.body if isinstance(fn.body, list) else [fn.body]
    for stmt in body:
        visit(stmt)
    free = loads - assigned - bound
    for sub in nested:
        free |= free_names(sub) - assigned - bound
    return free


class _Builder:
    """One discovered builder method plus its dataflow facts."""

    def __init__(self, cls_name: str, method: ast.FunctionDef):
        self.cls = cls_name
        self.method = method
        self.params = [p for p in _params_of(method) if p != "self"]
        self.taint: dict = {}      # local name -> set of tokens
        self.funcdefs: dict = {}   # local def/lambda name -> [nodes]
        self.key_tuple = None
        self.jit_calls: list = []
        self._scan()

    # tokens: ("param", name) | ("self",) | ("shape",)
    def _expr_tokens(self, expr) -> set:
        toks = set()
        for n in ast.walk(expr):
            if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Load):
                if n.id == "self":
                    toks.add(("self",))
                elif n.id in self.params:
                    toks.add(("param", n.id))
                elif n.id in self.taint:
                    toks |= self.taint[n.id]
            elif isinstance(n, ast.Attribute) and n.attr in _SHAPE_ATTRS:
                toks.add(("shape",))
            elif (isinstance(n, ast.Call) and isinstance(n.func, ast.Name)
                  and n.func.id == "len"):
                toks.add(("shape",))
        return toks

    def _scan(self):
        for node in ast.walk(self.method):
            if isinstance(node, ast.Assign):
                toks = self._expr_tokens(node.value)
                for tgt in node.targets:
                    names = ([tgt] if isinstance(tgt, ast.Name)
                             else [e for e in ast.walk(tgt)
                                   if isinstance(e, ast.Name)])
                    for nm in names:
                        if isinstance(nm.ctx, ast.Store):
                            self.taint.setdefault(nm.id, set())
                            self.taint[nm.id] |= toks
                    if isinstance(tgt, ast.Name) and tgt.id == "key" \
                            and isinstance(node.value, ast.Tuple):
                        self.key_tuple = node.value
                    if isinstance(tgt, ast.Name) \
                            and isinstance(node.value, ast.Lambda):
                        self.funcdefs.setdefault(tgt.id, []).append(node.value)
            elif isinstance(node, ast.FunctionDef) and node is not self.method:
                self.funcdefs.setdefault(node.name, []).append(node)
            elif isinstance(node, ast.Call):
                f = node.func
                if (isinstance(f, ast.Attribute) and f.attr in ("jit", "pjit")
                        and isinstance(f.value, ast.Name)
                        and f.value.id == "jax"):
                    self.jit_calls.append(node)

    # ------------------------------------------------------------- analysis
    def key_names(self) -> set:
        return {n.id for n in ast.walk(self.key_tuple)
                if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Load)}

    def key_has_kind(self) -> bool:
        return any(isinstance(n, ast.Call)
                   and isinstance(n.func, ast.Attribute)
                   and n.func.attr == "_kind"
                   for n in ast.walk(self.key_tuple))

    def family(self):
        first = self.key_tuple.elts[0] if self.key_tuple.elts else None
        return first.value if isinstance(first, ast.Constant) else None

    def examined_names(self) -> set:
        """Names whose values are baked into the jitted program: the
        closure's free variables plus non-sharding jit arguments."""
        out = set()
        for call in self.jit_calls:
            if call.args:
                fnarg = call.args[0]
                if isinstance(fnarg, ast.Lambda):
                    out |= free_names(fnarg)
                elif isinstance(fnarg, ast.Name):
                    if fnarg.id in self.funcdefs:
                        for d in self.funcdefs[fnarg.id]:
                            out |= free_names(d)
                    else:
                        out.add(fnarg.id)
            for kw in call.keywords:
                if kw.arg in _SHARDING_KWARGS:
                    continue
                out |= {n.id for n in ast.walk(kw.value)
                        if isinstance(n, ast.Name)
                        and isinstance(n.ctx, ast.Load)}
        return out

    def resolve(self, name: str, seen=None) -> set:
        """Tokens a free name ultimately depends on."""
        seen = seen or set()
        if name in seen:
            return set()
        seen.add(name)
        if name == "self":
            return {("self",)}
        if name in self.params:
            return {("param", name)}
        toks = set(self.taint.get(name, set()))
        if name in self.funcdefs:
            for d in self.funcdefs[name]:
                for sub in free_names(d):
                    toks |= self.resolve(sub, seen)
        return toks


def _cachey(param: str) -> bool:
    return ("cache" in param or param.endswith("state")
            or param in ("state", "one", "pstate"))


def _module_exempt(tree: ast.Module) -> dict:
    """The scanned module's own ``KEY_EXEMPT = {...}`` literal (no import —
    the pass must work on fixture files that cannot be imported)."""
    for node in tree.body:
        if isinstance(node, ast.Assign):
            for tgt in node.targets:
                if isinstance(tgt, ast.Name) and tgt.id == "KEY_EXEMPT" \
                        and isinstance(node.value, ast.Dict):
                    return {k.value: True for k in node.value.keys
                            if isinstance(k, ast.Constant)}
    return {}


def run(path, exempt: dict | None = None) -> PassResult:
    path = Path(path)
    tree = ast.parse(path.read_text(encoding="utf-8"))
    if exempt is None:
        exempt = _module_exempt(tree)
    violations: list[Violation] = []
    builders: list[_Builder] = []

    for cls in [n for n in ast.walk(tree) if isinstance(n, ast.ClassDef)]:
        for meth in [n for n in cls.body if isinstance(n, ast.FunctionDef)]:
            # a builder both assigns a ``key`` tuple and stores a program
            has_store = any(
                isinstance(n, ast.Subscript)
                and isinstance(n.ctx, ast.Store)
                and isinstance(n.value, ast.Attribute)
                and n.value.attr == "_programs"
                for n in ast.walk(meth))
            b = _Builder(cls.name, meth)
            if b.key_tuple is None or not has_store:
                continue
            builders.append(b)

            where = f"{path.name}:{meth.lineno} {cls.name}.{meth.name}"
            family = b.family()
            if family in exempt:
                continue
            knames = b.key_names()
            self_derived = False
            for name in sorted(b.examined_names()):
                for tok in b.resolve(name):
                    if tok == ("self",):
                        self_derived = True
                    elif tok[0] == "param" and tok[1] not in knames:
                        violations.append(Violation(
                            "keys", where, "key-param",
                            f"builder bakes parameter '{tok[1]}' (via "
                            f"'{name}') into the program but '{tok[1]}' is "
                            f"not in the cache key"))
                if ("shape",) in b.taint.get(name, set()) \
                        and name not in knames:
                    violations.append(Violation(
                        "keys", where, "key-shape",
                        f"shape-derived '{name}' is baked into the program "
                        f"but missing from the cache key"))
            if self_derived and any(_cachey(p) for p in b.params) \
                    and not b.key_has_kind():
                violations.append(Violation(
                    "keys", where, "key-kind",
                    "closure reaches instance state over a cache/state "
                    "template but the key has no self._kind(...) component "
                    "(add it or list the family in KEY_EXEMPT)"))

    return PassResult("keys", violations, {
        "builders": len(builders),
        "exempt": sorted(exempt),
        "file": str(path),
    })
