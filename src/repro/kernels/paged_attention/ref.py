"""Page-table-native decode attention: XLA reference + the accumulation
contract.

``block_decode_attention`` is the ONE algorithm every impl in this package
follows: an online-softmax ``lax.scan`` over fixed-size KV *blocks* (pages),
carrying ``(running max, sum-of-exp, weighted-V accumulator)`` per query row.
Blocks are visited in increasing logical order and a fully-masked block is an
exact identity step on the carry:

    m_new  = max(m_prev, max(s_block))   -> m_prev          (all s == -1e30)
    alpha  = exp(m_prev - m_new)         -> exp(0) == 1.0   (exact)
    l_new  = l_prev * 1.0 + sum(0.0)     -> l_prev          (exact)
    acc    = acc * 1.0 + P@V(P == 0.0)   -> acc             (exact)

so SKIPPING a fully-masked block — which is all an unmapped page can ever be,
because every read of the trash page is position-masked to a hard zero —
produces bitwise-identical output to processing it.  That identity, not any
property of XLA's reduction lowering, is what makes the page-native path
reproduce the dense ring path bit-for-bit: the ring caller scans ALL logical
blocks of its (B, C) cache, the paged caller scans only the mapped subset in
the same logical order, and the carries agree at every common block.
(Compacting pages through the dense chunked-softmax ``_xla_attention`` was
measured to drift by ~1 ulp on XLA CPU — its single-chunk reductions are not
zero-removal-invariant — which is why this package owns its own scan.)

``paged_attention_xla`` is the no-materialize fallback: a *block-bucketed*
gather — only the pages named by the compacted per-row page list are pulled
from the pool, so per-token cost is O(batch-max mapped pages), independent of
the logical capacity — followed by the block scan.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

_NEG_INF = -1e30


def block_decode_attention(
    q: jax.Array,        # (B, m, Hq, Dk)   m small (decode/probe positions)
    kb: jax.Array,       # (B, NBK, ps, Hkv, Dk)  KV blocks, logical order
    vb: jax.Array,       # (B, NBK, ps, Hkv, Dv)
    bpos: jax.Array,     # (B, NBK, ps) int32 slot positions (-1 = masked)
    q_pos: jax.Array,    # (B, m)
    *,
    scale: float,
    window: int = 0,
) -> jax.Array:          # (B, m, Hq, Dv)
    """Sequential per-block online softmax — THE accumulation-order contract
    shared by the ring (all blocks) and paged (mapped blocks only) callers."""
    B, m, Hq, Dk = q.shape
    Hkv = kb.shape[3]
    Dv = vb.shape[-1]
    g = Hq // Hkv

    # matmul inputs stay in storage dtype, f32 only in accumulators — the
    # same discipline as flash_attention's XLA path (§Perf P3')
    qf = q * jnp.asarray(scale, q.dtype)

    def body(carry, xs):
        m_prev, l_prev, acc = carry
        kcb, vcb, pb = xs                     # (B, ps, Hkv, D*), (B, ps)
        s = jnp.einsum("bqhd,bkhd->bhqk", qf, jnp.repeat(kcb, g, axis=2),
                       preferred_element_type=jnp.float32)  # (B, Hq, m, ps)
        valid = pb[:, None, None, :] >= 0
        valid &= pb[:, None, None, :] <= q_pos[:, None, :, None]
        if window:
            valid &= (q_pos[:, None, :, None] - pb[:, None, None, :]) < window
        s = jnp.where(valid, s, _NEG_INF)
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.where(valid, jnp.exp(s - m_new[..., None]), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + p.sum(axis=-1)
        pv = jnp.einsum("bhqk,bkhd->bhqd", p.astype(vcb.dtype),
                        jnp.repeat(vcb, g, axis=2),
                        preferred_element_type=jnp.float32)
        acc = acc * alpha[..., None] + pv
        return (m_new, l_new, acc), None

    init = (
        jnp.full((B, Hq, m), _NEG_INF, jnp.float32),
        jnp.zeros((B, Hq, m), jnp.float32),
        jnp.zeros((B, Hq, m, Dv), jnp.float32),
    )
    (_, l, acc), _ = lax.scan(
        body, init,
        (jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0),
         jnp.moveaxis(bpos, 1, 0)),
    )
    out = jnp.where(l[..., None] > 0, acc / jnp.maximum(l[..., None], 1e-30),
                    0.0)
    return out.transpose(0, 2, 1, 3).astype(q.dtype)   # (B, m, Hq, Dv)


def paged_attention_xla(
    q: jax.Array,        # (B, m, Hq, Dk)
    k_pool: jax.Array,   # (P, ps, Hkv, Dk)  physical page pool
    v_pool: jax.Array,   # (P, ps, Hkv, Dv)
    pages: jax.Array,    # (B, NBK) int32 physical page per mapped-block rank
    bpos: jax.Array,     # (B, NBK, ps) int32 positions (-1 = masked)
    q_pos: jax.Array,    # (B, m)
    *,
    scale: float,
    window: int = 0,
) -> jax.Array:
    """Block-bucketed gather of only-mapped pages, then the block scan.

    The gather touches ``NBK`` pages per row — the compacted mapped-block
    list, NOT the logical extent — so HBM traffic and compute are
    O(mapped pages).  Padding ranks point at the trash page with all
    positions -1: identity steps (see module docstring)."""
    kb = k_pool[pages]                         # (B, NBK, ps, Hkv, Dk)
    vb = v_pool[pages]
    return block_decode_attention(q, kb, vb, bpos, q_pos,
                                  scale=scale, window=window)
