"""Dispatching wrapper for page-table-native decode attention.

Two entry points, one algorithm (``ref.block_decode_attention``'s
sequential per-page online softmax):

* ``paged_decode_attention`` — decode/probe attention straight off the
  physical page pools through a compacted per-row page list (no gathered
  logical view; O(mapped pages) per token).
* ``ring_decode_attention``  — the SAME algorithm over a dense ring cache,
  viewed as logical blocks via a free reshape (all blocks "mapped").

Because the paged caller visits exactly the mapped subsequence of the
blocks the ring caller visits — and skipped blocks are exact identity
steps (ref.py) — a paged serve and a ring serve through these ops produce
bit-identical outputs.  That per-impl invariant is what the serving stack's
``attn_impl != "gather"`` modes rely on (docs/architecture.md §Paged
attention kernel).

``impl``: ``auto`` (pallas on TPU, else xla), ``xla`` (the block-scan
reference), ``pallas`` (the kernel; on non-TPU backends it runs in
interpret mode so the path is CPU-testable end to end).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.paged_attention.ref import (
    block_decode_attention,
    paged_attention_xla,
)

#: physical page id reserved as the trash page (serving.cache.PAGE_TRASH);
#: duplicated here so the kernel package stays import-light
PAGE_TRASH = 0


def _resolve(impl: str, interpret: bool) -> tuple[str, bool]:
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "xla"
    if impl == "pallas" and jax.default_backend() != "tpu":
        interpret = True              # CPU: interpret-mode kernel
    return impl, interpret


def block_positions(kv_pos: jax.Array, pages: jax.Array,
                    logical: jax.Array, page_size: int) -> jax.Array:
    """Per-bucket slot positions from the logical ``pos`` array.

    kv_pos: (B, C); pages/logical: (B, NBK).  Rank ``j`` of row ``b`` holds
    logical block ``logical[b, j]`` — its positions are the corresponding
    ps-slice of ``kv_pos``.  Ranks mapped to the trash page are forced to
    -1 (fully masked): THE hard-zero discipline that makes unmapped /
    padding ranks exact identity steps."""
    B, C = kv_pos.shape
    pos_blocks = kv_pos.reshape(B, C // page_size, page_size)
    bpos = jnp.take_along_axis(pos_blocks, logical[:, :, None], axis=1)
    return jnp.where((pages != PAGE_TRASH)[:, :, None], bpos, -1)


def paged_decode_attention(
    q: jax.Array,        # (B, m, Hq, Dk)
    k_pool: jax.Array,   # (P, ps, Hkv, Dk)
    v_pool: jax.Array,   # (P, ps, Hkv, Dv)
    pages: jax.Array,    # (B, NBK) int32
    counts: jax.Array,   # (B,) int32 mapped ranks per row
    bpos: jax.Array,     # (B, NBK, ps) int32 (-1 = masked)
    q_pos: jax.Array,    # (B, m)
    *,
    window: int = 0,
    scale: float | None = None,
    impl: str = "auto",
    interpret: bool = False,
) -> jax.Array:
    scale = scale if scale is not None else 1.0 / (q.shape[-1] ** 0.5)
    impl, interpret = _resolve(impl, interpret)
    if impl == "pallas":
        from repro.kernels.paged_attention.kernel import paged_attention_pallas

        return paged_attention_pallas(
            q, k_pool, v_pool, pages, counts, bpos, q_pos,
            window=window, scale=scale, interpret=interpret,
        )
    return paged_attention_xla(q, k_pool, v_pool, pages, bpos, q_pos,
                               scale=scale, window=window)


def ring_decode_attention(
    q: jax.Array,        # (B, m, Hq, Dk)
    k: jax.Array,        # (B, C, Hkv, Dk) dense ring cache
    v: jax.Array,        # (B, C, Hkv, Dv)
    q_pos: jax.Array,    # (B, m)
    kv_pos: jax.Array,   # (B, C)
    *,
    page_size: int,
    window: int = 0,
    scale: float | None = None,
    impl: str = "auto",
    interpret: bool = False,
) -> jax.Array:
    """The ring cache through the block algorithm: every logical block is
    "mapped" at its own rank, so the scan covers the whole capacity in
    logical order — the dense comparator whose accumulation the paged path
    reproduces bit-for-bit.  A capacity that is not a page multiple is
    padded with masked slots (an exact no-op: appended identity steps)."""
    scale = scale if scale is not None else 1.0 / (q.shape[-1] ** 0.5)
    impl, interpret = _resolve(impl, interpret)
    B, C = kv_pos.shape
    pad = (-C) % page_size
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pad)), constant_values=-1)
    Cp = kv_pos.shape[1]
    NB = Cp // page_size
    bpos = kv_pos.reshape(B, NB, page_size)
    if impl == "pallas":
        from repro.kernels.paged_attention.kernel import paged_attention_pallas

        # the dense rows become a (B*NB)-page pool with an identity list
        Hkv, Dk = k.shape[2], k.shape[3]
        pool_k = k.reshape(B * NB, page_size, Hkv, Dk)
        pool_v = v.reshape(B * NB, page_size, Hkv, v.shape[-1])
        ranks = jnp.arange(NB, dtype=jnp.int32)[None, :]
        pages = jnp.arange(B, dtype=jnp.int32)[:, None] * NB + ranks
        counts = jnp.full((B,), NB, jnp.int32)
        return paged_attention_pallas(
            q, pool_k, pool_v, pages, counts, bpos, q_pos,
            window=window, scale=scale, interpret=interpret,
        )
    kb = k.reshape(B, NB, page_size, k.shape[2], k.shape[3])
    vb = v.reshape(B, NB, page_size, v.shape[2], v.shape[3])
    return block_decode_attention(q, kb, vb, bpos, q_pos,
                                  scale=scale, window=window)
