"""Pallas TPU page-table-native flash-decode kernel.

Unlike ``decode_attention`` (which reads a dense per-row (B, C, Hkv, hd)
cache), this kernel reads K/V **directly from the physical page pools**
through a per-row compacted page list: the grid is (batch, kv-head,
page-rank) and the BlockSpec index maps resolve rank ``j`` of row ``b`` to
physical page ``pages[b, j]`` via scalar prefetch
(``pltpu.PrefetchScalarGridSpec``), so the only KV bytes that ever move are
the mapped pages — per-token cost is O(mapped pages), independent of the
logical cache capacity.  Ranks at or past ``counts[b]`` skip the whole
accumulation (``pl.when``) and their index map points at the trash page
(page 0), so the DMA for a skipped step is one page of dead weight at worst.

GQA head grouping and the hard-zero masking discipline are carried over
verbatim from ``decode_attention``: the q tile is (m * group_size, Dk) per
kv head, masking uses explicit per-slot positions, and masked probabilities
are exact 0.0 — combined with the sequential per-page accumulation order of
``ref.block_decode_attention`` this keeps the paged==ring bit-exactness
argument intact (skipped pages are identity steps; see ref.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _kernel(pages_ref, counts_ref, qp_ref, bpos_ref, q_ref, k_ref, v_ref,
            o_ref, m_scr, l_scr, acc_scr, *, scale, window, n_ranks):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # ranks past the row's mapped count hold no KV: skip the accumulation
    # entirely (the identity-step argument in ref.py makes this exact)
    @pl.when(j < counts_ref[b])
    def _accumulate():
        q = q_ref[0, 0].astype(jnp.float32) * scale   # (rows, Dk) rows = m*g
        k = k_ref[0, :, 0, :].astype(jnp.float32)     # (ps, Dk)
        v = v_ref[0, :, 0, :].astype(jnp.float32)     # (ps, Dv)
        qp = qp_ref[0]                                # (rows,)
        kp = bpos_ref[0, 0]                           # (ps,)

        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        valid = (kp[None, :] >= 0) & (kp[None, :] <= qp[:, None])
        if window:
            valid &= (qp[:, None] - kp[None, :]) < window
        s = jnp.where(valid, s, _NEG_INF)

        m_prev, l_prev = m_scr[...], l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.where(valid, jnp.exp(s - m_new[:, None]), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        m_scr[...] = m_new
        l_scr[...] = l_prev * alpha + jnp.sum(p, axis=-1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + pv

    @pl.when(j == n_ranks - 1)
    def _emit():
        l = l_scr[...]
        out = jnp.where(l[:, None] > 0,
                        acc_scr[...] / jnp.maximum(l[:, None], 1e-30), 0.0)
        o_ref[0, 0] = out.astype(o_ref.dtype)


def paged_attention_pallas(
    q: jax.Array,        # (B, m, Hq, Dk)   m small (decode/probe positions)
    k_pool: jax.Array,   # (P, ps, Hkv, Dk) physical page pool
    v_pool: jax.Array,   # (P, ps, Hkv, Dv)
    pages: jax.Array,    # (B, NBK) int32 physical page per mapped rank
    counts: jax.Array,   # (B,) int32 mapped ranks per row
    bpos: jax.Array,     # (B, NBK, ps) int32 positions (-1 = masked)
    q_pos: jax.Array,    # (B, m)
    *,
    window: int = 0,
    scale: float | None = None,
    interpret: bool = False,
) -> jax.Array:
    B, m, Hq, Dk = q.shape
    ps, Hkv = k_pool.shape[1], k_pool.shape[2]
    Dv = v_pool.shape[-1]
    NBK = pages.shape[1]
    g = Hq // Hkv
    rows = m * g
    scale = scale if scale is not None else 1.0 / (Dk ** 0.5)

    # regroup q to (B, Hkv, m*g, Dk): row r = position (r // g), head (r % g)
    qg = q.reshape(B, m, Hkv, g, Dk).transpose(0, 2, 1, 3, 4).reshape(
        B, Hkv, rows, Dk)
    qpg = jnp.broadcast_to(q_pos[:, :, None], (B, m, g)).reshape(B, rows)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,               # pages, counts
        grid=(B, Hkv, NBK),
        in_specs=[
            pl.BlockSpec((1, rows), lambda b, h, j, pg, ct: (b, 0)),
            pl.BlockSpec((1, 1, ps), lambda b, h, j, pg, ct: (b, j, 0)),
            pl.BlockSpec((1, 1, rows, Dk), lambda b, h, j, pg, ct: (b, h, 0, 0)),
            # the page-table hop: rank j of row b -> physical pool page
            pl.BlockSpec((1, ps, 1, Dk), lambda b, h, j, pg, ct: (pg[b, j], 0, h, 0)),
            pl.BlockSpec((1, ps, 1, Dv), lambda b, h, j, pg, ct: (pg[b, j], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, rows, Dv), lambda b, h, j, pg, ct: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((rows,), jnp.float32),
            pltpu.VMEM((rows,), jnp.float32),
            pltpu.VMEM((rows, Dv), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, window=window, n_ranks=NBK),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, rows, Dv), q.dtype),
        interpret=interpret,
    )(jnp.asarray(pages, jnp.int32), jnp.asarray(counts, jnp.int32),
      qpg, bpos, qg, k_pool, v_pool)
    # back to (B, m, Hq, Dv)
    return out.reshape(B, Hkv, m, g, Dv).transpose(0, 2, 1, 3, 4).reshape(
        B, m, Hq, Dv)
