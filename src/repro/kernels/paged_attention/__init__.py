from repro.kernels.paged_attention.ops import (  # noqa: F401
    block_positions,
    paged_decode_attention,
    ring_decode_attention,
)
