"""Pallas TPU flash-attention kernel (prefill / train).

TPU-native tiling: the grid iterates (batch, q-head, q-tile, kv-tile) with
the kv-tile innermost; FlashAttention-style running (max, sum, acc)
accumulators live in VMEM scratch so the (Sq, Skv) score matrix never
touches HBM.  GQA is expressed through the kv BlockSpec index map
(q heads h share kv head h // g) — no materialized head repetition.

Masking uses explicit per-position integer ids (negative = invalid slot),
which uniformly encodes causal prefill, left-padded batches, sliding
windows, and ring-buffer caches.

Block shapes default to (128, 128) on (Sq, Skv) — lane-aligned for the MXU;
head_dim rides along whole (64..256 for the assigned archs, padded to the
lane width by Pallas when 80/192).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _kernel(qp_ref, kp_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
            *, scale, causal, window, n_kv_tiles):
    kv_i = pl.program_id(3)

    @pl.when(kv_i == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, :, 0, :].astype(jnp.float32) * scale   # (bq, Dk)
    k = k_ref[0, :, 0, :].astype(jnp.float32)           # (bkv, Dk)
    v = v_ref[0, :, 0, :].astype(jnp.float32)           # (bkv, Dv)
    qp = qp_ref[0]                                      # (bq,)
    kp = kp_ref[0]                                      # (bkv,)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (bq, bkv)

    valid = kp[None, :] >= 0
    if causal:
        valid &= kp[None, :] <= qp[:, None]
    if window:
        valid &= (qp[:, None] - kp[None, :]) < window
    s = jnp.where(valid, s, _NEG_INF)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(valid, p, 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_prev * alpha + jnp.sum(p, axis=-1)
    pv = jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (bq, Dv)
    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc_scr[...] * alpha[:, None] + pv

    @pl.when(kv_i == n_kv_tiles - 1)
    def _emit():
        l = l_scr[...]
        out = jnp.where(l[:, None] > 0, acc_scr[...] / jnp.maximum(l[:, None], 1e-30), 0.0)
        o_ref[0, :, 0, :] = out.astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,      # (B, Sq, Hq, Dk)
    k: jax.Array,      # (B, Skv, Hkv, Dk)
    v: jax.Array,      # (B, Skv, Hkv, Dv)
    q_pos: jax.Array,  # (B, Sq) int32
    kv_pos: jax.Array, # (B, Skv) int32
    *,
    causal: bool = True,
    window: int = 0,
    scale: float | None = None,
    block_q: int = 128,
    block_kv: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, Sq, Hq, Dk = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    g = Hq // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(Dk)

    block_q = min(block_q, Sq)
    block_kv = min(block_kv, Skv)

    # pad sequence dims to tile multiples; padded slots get position -1
    def pad_seq(x, mult, value=0):
        pad = (-x.shape[1]) % mult
        if pad == 0:
            return x
        w = [(0, 0)] * x.ndim
        w[1] = (0, pad)
        return jnp.pad(x, w, constant_values=value)

    q_p, qp_p = pad_seq(q, block_q), pad_seq(q_pos, block_q, -1)
    k_p, v_p, kp_p = pad_seq(k, block_kv), pad_seq(v, block_kv), pad_seq(kv_pos, block_kv, -1)
    Sq_p, Skv_p = q_p.shape[1], k_p.shape[1]
    n_q, n_kv = Sq_p // block_q, Skv_p // block_kv

    grid = (B, Hq, n_q, n_kv)

    out = pl.pallas_call(
        functools.partial(
            _kernel, scale=scale, causal=causal, window=window, n_kv_tiles=n_kv
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q), lambda b, h, i, j: (b, i)),           # q_pos
            pl.BlockSpec((1, block_kv), lambda b, h, i, j: (b, j)),          # kv_pos
            pl.BlockSpec((1, block_q, 1, Dk), lambda b, h, i, j: (b, i, h, 0)),
            pl.BlockSpec((1, block_kv, 1, Dk), lambda b, h, i, j: (b, j, h // g, 0)),
            pl.BlockSpec((1, block_kv, 1, Dv), lambda b, h, i, j: (b, j, h // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, Dv), lambda b, h, i, j: (b, i, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Sq_p, Hq, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, Dv), jnp.float32),
        ],
        interpret=interpret,
    )(qp_p, kp_p, q_p, k_p, v_p)
    return out[:, :Sq]
