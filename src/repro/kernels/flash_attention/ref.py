"""Pure-jnp oracle for masked GQA attention with explicit positions.

This is the *semantic definition* used by kernel tests and small-shape code
paths.  It materializes the full (B, H, Sq, Skv) score matrix — fine for
tests, never used at production sequence lengths.
"""
from __future__ import annotations

import jax.numpy as jnp
import jax


def attention_ref(
    q: jax.Array,            # (B, Sq, Hq, Dk)
    k: jax.Array,            # (B, Skv, Hkv, Dk)
    v: jax.Array,            # (B, Skv, Hkv, Dv)
    q_pos: jax.Array,        # (B, Sq) int32 absolute positions (< 0 = invalid)
    kv_pos: jax.Array,       # (B, Skv) int32 absolute positions (< 0 = invalid)
    *,
    causal: bool = True,
    window: int = 0,         # >0: only attend to kv with q_pos - kv_pos < window
    scale: float | None = None,
) -> jax.Array:              # (B, Sq, Hq, Dv)
    B, Sq, Hq, Dk = q.shape
    Hkv = k.shape[2]
    g = Hq // Hkv
    scale = scale if scale is not None else 1.0 / (Dk ** 0.5)

    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    # expand kv heads for GQA
    kf = jnp.repeat(kf, g, axis=2)   # (B, Skv, Hq, Dk)
    vf = jnp.repeat(vf, g, axis=2)

    scores = jnp.einsum("bqhd,bkhd->bhqk", qf, kf)

    valid = kv_pos[:, None, None, :] >= 0
    if causal:
        valid &= kv_pos[:, None, None, :] <= q_pos[:, None, :, None]
    if window:
        valid &= (q_pos[:, None, :, None] - kv_pos[:, None, None, :]) < window
    scores = jnp.where(valid, scores, -jnp.inf)

    # fully-masked rows (e.g. padded q positions) produce zeros
    all_masked = ~jnp.any(valid, axis=-1, keepdims=True)
    scores = jnp.where(all_masked, 0.0, scores)
    probs = jnp.where(all_masked, 0.0, jax.nn.softmax(scores, axis=-1))
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, vf)
    return out.astype(q.dtype)
