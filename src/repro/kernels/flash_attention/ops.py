"""Dispatching attention op.

``attention(...)`` picks the implementation:

* ``pallas``  — the TPU flash kernel (``kernel.py``); used on TPU backends
  and under ``interpret=True`` in tests.
* ``xla``     — a chunked online-softmax implementation in pure jnp
  (`lax.scan` over query and kv tiles), memory-bounded like flash attention.
  This is what the CPU dry-run lowers, and the fallback on non-TPU backends.
* ``ref``     — the naive oracle (tests / tiny shapes only).

All implementations share the semantics of ``ref.attention_ref``: explicit
integer positions, position < 0 means invalid, causal + sliding-window
masking, GQA via ``Hq % Hkv == 0``, and Dv may differ from Dk (MLA).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax import lax

from repro.kernels.flash_attention.ref import attention_ref

_NEG_INF = -1e30


def _pad_to(x: jax.Array, axis: int, mult: int, value=0):
    s = x.shape[axis]
    pad = (-s) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def _xla_attention(q, k, v, q_pos, kv_pos, *, causal, window, scale,
                   q_chunk=256, kv_chunk=2048):
    """Chunked online-softmax attention in pure XLA ops.

    scan over q chunks (outer) and kv chunks (inner, online accumulation) —
    peak score buffer is (B, Hq, q_chunk, kv_chunk) f32.
    """
    B, Sq, Hq, Dk = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    g = Hq // Hkv
    orig_sq = Sq

    q_chunk = min(q_chunk, max(16, Sq))
    kv_chunk = min(kv_chunk, max(128, Skv))

    q = _pad_to(q, 1, q_chunk)
    q_pos = _pad_to(q_pos, 1, q_chunk, value=-1)
    k = _pad_to(k, 1, kv_chunk)
    v = _pad_to(v, 1, kv_chunk)
    kv_pos = _pad_to(kv_pos, 1, kv_chunk, value=-1)
    Sq_p, Skv_p = q.shape[1], k.shape[1]
    nq, nkv = Sq_p // q_chunk, Skv_p // kv_chunk

    # keep matmul INPUTS in their storage dtype (bf16) — f32 only in the
    # accumulators (preferred_element_type) and softmax stats.  Pre-casting
    # to f32 made GSPMD move/gather attention inputs at 2x the bytes
    # (§Perf P3' profile).
    qf = (q * jnp.asarray(scale, q.dtype)).reshape(B, nq, q_chunk, Hq, Dk)
    qpf = q_pos.reshape(B, nq, q_chunk)
    kf = k.reshape(B, nkv, kv_chunk, Hkv, Dk)
    vf = v.reshape(B, nkv, kv_chunk, Hkv, Dv)
    kpf = kv_pos.reshape(B, nkv, kv_chunk)

    def q_step(_, q_in):
        qc, qp = q_in  # (B, cq, Hq, Dk), (B, cq)

        def kv_step(carry, kv_in):
            m_prev, l_prev, acc = carry
            kc, vc, kp = kv_in  # (B, ckv, Hkv, Dk/v), (B, ckv)
            s = jnp.einsum("bqhd,bkhd->bhqk", qc, jnp.repeat(kc, g, axis=2),
                           preferred_element_type=jnp.float32)
            # (B, Hq, cq, ckv) f32
            valid = kp[:, None, None, :] >= 0
            if causal:
                valid &= kp[:, None, None, :] <= qp[:, None, :, None]
            if window:
                valid &= (qp[:, None, :, None] - kp[:, None, None, :]) < window
            s = jnp.where(valid, s, _NEG_INF)
            m_new = jnp.maximum(m_prev, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            p = jnp.where(valid, p, 0.0)
            alpha = jnp.exp(m_prev - m_new)
            l_new = l_prev * alpha + p.sum(axis=-1)
            pv = jnp.einsum("bhqk,bkhd->bhqd", p.astype(vc.dtype),
                            jnp.repeat(vc, g, axis=2),
                            preferred_element_type=jnp.float32)
            acc = acc * alpha[..., None] + pv
            return (m_new, l_new, acc), None

        init = (
            jnp.full((B, Hq, q_chunk), _NEG_INF, jnp.float32),
            jnp.zeros((B, Hq, q_chunk), jnp.float32),
            jnp.zeros((B, Hq, q_chunk, Dv), jnp.float32),
        )
        (m, l, acc), _ = lax.scan(
            kv_step,
            init,
            (
                jnp.moveaxis(kf, 1, 0),
                jnp.moveaxis(vf, 1, 0),
                jnp.moveaxis(kpf, 1, 0),
            ),
        )
        out = jnp.where(l[..., None] > 0, acc / jnp.maximum(l[..., None], 1e-30), 0.0)
        return None, out.transpose(0, 2, 1, 3)  # (B, cq, Hq, Dv)

    _, out = lax.scan(
        q_step, None, (jnp.moveaxis(qf, 1, 0), jnp.moveaxis(qpf, 1, 0))
    )  # (nq, B, cq, Hq, Dv)
    out = jnp.moveaxis(out, 0, 1).reshape(B, Sq_p, Hq, Dv)[:, :orig_sq]
    return out.astype(q.dtype)


def attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    q_pos: jax.Array,
    kv_pos: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    scale: float | None = None,
    impl: str = "auto",
    interpret: bool = False,
) -> jax.Array:
    """Masked (causal / sliding-window) GQA attention with explicit positions.

    q: (B, Sq, Hq, Dk); k: (B, Skv, Hkv, Dk); v: (B, Skv, Hkv, Dv);
    q_pos: (B, Sq) int32; kv_pos: (B, Skv) int32 (negative = invalid slot).
    Returns (B, Sq, Hq, Dv).
    """
    scale = scale if scale is not None else 1.0 / math.sqrt(q.shape[-1])
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "xla"
    if impl == "ref":
        return attention_ref(q, k, v, q_pos, kv_pos, causal=causal, window=window, scale=scale)
    if impl == "pallas":
        from repro.kernels.flash_attention.kernel import flash_attention_pallas

        return flash_attention_pallas(
            q, k, v, q_pos, kv_pos, causal=causal, window=window, scale=scale,
            interpret=interpret,
        )
    return _xla_attention(q, k, v, q_pos, kv_pos, causal=causal, window=window, scale=scale)
