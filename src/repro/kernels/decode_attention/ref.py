"""Oracle for flash-decode: identical semantics to flash_attention's ref
(explicit positions, GQA, Dv != Dk) — re-exported so the decode kernel has
its own named oracle for shape-sweep tests."""
from repro.kernels.flash_attention.ref import attention_ref as decode_attention_ref  # noqa: F401
