"""Pallas TPU flash-decode kernel (serve_step attention).

Decode reads the whole KV cache for m<=8 new query positions: the work is
KV-bound, so unlike the prefill kernel the grid parallelizes over
(batch, kv-head, kv-tile) and processes *all* q rows belonging to a kv head
at once — the q tile is (m * group_size, Dk), i.e. every q head in the GQA
group x every new position, which keeps the MXU busy on one (bkv, Dk) x
(Dk, m*g) matmul per tile instead of m separate vector products.

Ring-buffer caches (sliding window) are supported for free: masking uses
the explicit per-slot position array, so slot order never matters.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _kernel(qp_ref, kp_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
            *, scale, window, n_kv_tiles, rows):
    kv_j = pl.program_id(2)

    @pl.when(kv_j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32) * scale  # (rows, Dk)  rows = m*g
    k = k_ref[0, :, 0, :].astype(jnp.float32)    # (bkv, Dk)
    v = v_ref[0, :, 0, :].astype(jnp.float32)    # (bkv, Dv)
    qp = qp_ref[0]                               # (rows,)
    kp = kp_ref[0]                               # (bkv,)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (rows, bkv)
    valid = (kp[None, :] >= 0) & (kp[None, :] <= qp[:, None])
    if window:
        valid &= (qp[:, None] - kp[None, :]) < window
    s = jnp.where(valid, s, _NEG_INF)

    m_prev, l_prev = m_scr[...], l_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.where(valid, jnp.exp(s - m_new[:, None]), 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_prev * alpha + jnp.sum(p, axis=-1)
    pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc_scr[...] * alpha[:, None] + pv

    @pl.when(kv_j == n_kv_tiles - 1)
    def _emit():
        l = l_scr[...]
        out = jnp.where(l[:, None] > 0, acc_scr[...] / jnp.maximum(l[:, None], 1e-30), 0.0)
        o_ref[0, 0] = out.astype(o_ref.dtype)


def decode_attention_pallas(
    q: jax.Array,      # (B, m, Hq, Dk)   m small (decode/probe positions)
    k: jax.Array,      # (B, C, Hkv, Dk)  cache
    v: jax.Array,      # (B, C, Hkv, Dv)
    q_pos: jax.Array,  # (B, m)
    kv_pos: jax.Array, # (B, C)
    *,
    window: int = 0,
    scale: float | None = None,
    block_kv: int = 512,
    interpret: bool = False,
) -> jax.Array:
    B, m, Hq, Dk = q.shape
    C, Hkv = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    g = Hq // Hkv
    rows = m * g
    scale = scale if scale is not None else 1.0 / (Dk ** 0.5)
    block_kv = min(block_kv, C)

    pad_kv = (-C) % block_kv
    if pad_kv:
        k = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pad_kv)), constant_values=-1)
    Cp = k.shape[1]
    n_kv = Cp // block_kv

    # regroup q to (B, Hkv, m*g, Dk): row r = position (r // g), head-in-group (r % g)
    qg = q.reshape(B, m, Hkv, g, Dk).transpose(0, 2, 1, 3, 4).reshape(B, Hkv, rows, Dk)
    qpg = jnp.broadcast_to(q_pos[:, :, None], (B, m, g)).reshape(B, rows)

    grid = (B, Hkv, n_kv)
    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, window=window,
                          n_kv_tiles=n_kv, rows=rows),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, rows), lambda b, h, j: (b, 0)),
            pl.BlockSpec((1, block_kv), lambda b, h, j: (b, j)),
            pl.BlockSpec((1, 1, rows, Dk), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, block_kv, 1, Dk), lambda b, h, j: (b, j, h, 0)),
            pl.BlockSpec((1, block_kv, 1, Dv), lambda b, h, j: (b, j, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, rows, Dv), lambda b, h, j: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, rows, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((rows,), jnp.float32),
            pltpu.VMEM((rows,), jnp.float32),
            pltpu.VMEM((rows, Dv), jnp.float32),
        ],
        interpret=interpret,
    )(qpg, kv_pos, qg, k, v)
    # back to (B, m, Hq, Dv)
    return out.reshape(B, Hkv, m, g, Dv).transpose(0, 2, 1, 3, 4).reshape(B, m, Hq, Dv)
