"""Dispatching wrapper for flash-decode attention.

Semantics == ``flash_attention.ops.attention`` with causal=True; only the
execution strategy differs (KV-tile-parallel, q heads grouped per kv head).
The XLA fallback simply reuses the chunked attention implementation.
"""
from __future__ import annotations

import jax

from repro.kernels.flash_attention.ops import attention as _attention


def decode_attention(
    q: jax.Array,       # (B, m, Hq, Dk)
    k: jax.Array,       # (B, C, Hkv, Dk)
    v: jax.Array,       # (B, C, Hkv, Dv)
    q_pos: jax.Array,   # (B, m)
    kv_pos: jax.Array,  # (B, C)
    *,
    window: int = 0,
    scale: float | None = None,
    impl: str = "auto",
    interpret: bool = False,
) -> jax.Array:
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "xla"
    if impl == "pallas":
        from repro.kernels.decode_attention.kernel import decode_attention_pallas

        return decode_attention_pallas(
            q, k, v, q_pos, kv_pos, window=window, scale=scale, interpret=interpret
        )
    return _attention(
        q, k, v, q_pos, kv_pos, causal=True, window=window, scale=scale, impl=impl
    )
