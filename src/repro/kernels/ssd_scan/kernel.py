"""Pallas TPU kernel for the Mamba2 SSD chunk scan.

Grid: (B, nh, n_chunks) with the chunk dim innermost and *sequential* — the
running state h (N, hp) lives in VMEM scratch and is carried across chunk
iterations (the TPU grid executes in order, so scratch persistence encodes
the recurrence).  Per chunk the kernel computes, entirely in VMEM:

  intra:  Y += ((C B^T) * exp(segsum(logd))) @ U          (L x L MXU matmul)
  inter:  Y += (C @ h_prev) * exp(cumsum(logd))
  state:  h  = exp(sum logd) h_prev + (decay_to_end * B)^T @ U

L = chunk length (128 default) and N/hp are 64..128 — all matmul dims are
MXU-aligned.  B/C group sharing (n_groups < nh) is expressed through the
BlockSpec index map (head h reads group h // (nh/G)), mirroring the GQA
trick in the attention kernels.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(u_ref, d_ref, b_ref, c_ref, y_ref, hf_ref, h_scr, *, n_chunks, L):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    u = u_ref[0, :, 0, :].astype(jnp.float32)      # (L, hp)
    logd = d_ref[0, :, 0].astype(jnp.float32)      # (L,)
    b = b_ref[0, :, 0, :].astype(jnp.float32)      # (L, N)
    c = c_ref[0, :, 0, :].astype(jnp.float32)      # (L, N)

    cs = jnp.cumsum(logd)                           # (L,) inclusive
    # intra-chunk: M[t,s] = (c_t . b_s) * exp(cs_t - cs_s) for s <= t
    seg = cs[:, None] - cs[None, :]
    tri = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0) >= \
          jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    mask = jnp.where(tri, jnp.exp(seg), 0.0)
    cb = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (L, L)
    y = jax.lax.dot_general(cb * mask, u, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (L, hp)

    # inter-chunk: y_t += exp(cs_t) * c_t . h_prev
    h_prev = h_scr[...]                              # (N, hp)
    y += jnp.exp(cs)[:, None] * jax.lax.dot_general(
        c, h_prev, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    # state update: h = exp(cs_L) h_prev + sum_s exp(cs_L - cs_s) b_s u_s^T
    total = cs[-1]
    w = jnp.exp(total - cs)                          # (L,)
    bu = jax.lax.dot_general(b * w[:, None], u, (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (N, hp)
    h_scr[...] = jnp.exp(total) * h_prev + bu

    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    @pl.when(ci == n_chunks - 1)
    def _emit_state():
        hf_ref[0, 0] = h_scr[...].astype(hf_ref.dtype)


def ssd_scan_pallas(
    u: jax.Array,       # (B, S, nh, hp)
    logd: jax.Array,    # (B, S, nh)
    Bm: jax.Array,      # (B, S, G, N)
    Cm: jax.Array,      # (B, S, G, N)
    *,
    chunk: int = 128,
    interpret: bool = False,
):
    """Returns (y (B,S,nh,hp), h_final (B,nh,N,hp)).  h0 must be zero (the
    models pass initial state through ``ssd_chunked`` instead when resuming —
    the kernel targets the train/prefill-from-scratch hot path)."""
    Bsz, S, nh, hp = u.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = nh // G
    L = min(chunk, S)
    pad = (-S) % L
    if pad:
        u = jnp.pad(u, ((0, 0), (0, pad), (0, 0), (0, 0)))
        logd = jnp.pad(logd, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Sp = S + pad
    nc = Sp // L

    y, hf = pl.pallas_call(
        functools.partial(_kernel, n_chunks=nc, L=L),
        grid=(Bsz, nh, nc),
        in_specs=[
            pl.BlockSpec((1, L, 1, hp), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, L, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1, L, 1, N), lambda b, h, c, rep=rep: (b, c, h // rep, 0)),
            pl.BlockSpec((1, L, 1, N), lambda b, h, c, rep=rep: (b, c, h // rep, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, L, 1, hp), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, 1, N, hp), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bsz, Sp, nh, hp), u.dtype),
            jax.ShapeDtypeStruct((Bsz, nh, N, hp), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, hp), jnp.float32)],
        interpret=interpret,
    )(u, logd, Bm, Cm)
    return y[:, :S], hf
