"""Pure-jnp oracle for the Mamba2 SSD scan: the naive O(S) recurrence

    h_t = exp(logd_t) h_{t-1} + B_t (u_t)^T          (per head)
    y_t = C_t . h_t

u: (B,S,nh,hp); logd: (B,S,nh); Bm/Cm: (B,S,G,N) with nh % G == 0.
Returns (y (B,S,nh,hp), h_final (B,nh,N,hp)).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_scan_ref(u, logd, Bm, Cm, h0=None):
    Bsz, S, nh, hp = u.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = nh // G
    h = jnp.zeros((Bsz, nh, N, hp), jnp.float32) if h0 is None else h0.astype(jnp.float32)
    ys = []
    for t in range(S):
        a = jnp.exp(logd[:, t].astype(jnp.float32))              # (B,nh)
        b = jnp.repeat(Bm[:, t], rep, axis=1).astype(jnp.float32)  # (B,nh,N)
        c = jnp.repeat(Cm[:, t], rep, axis=1).astype(jnp.float32)
        h = a[..., None, None] * h + jnp.einsum("bhn,bhp->bhnp", b, u[:, t].astype(jnp.float32))
        ys.append(jnp.einsum("bhn,bhnp->bhp", c, h))
    y = jnp.stack(ys, axis=1)
    return y.astype(u.dtype), h
