"""Dispatching wrapper for the SSD scan.

XLA fallback = the chunked associative-scan implementation in
``models.ssm.ssd_chunked`` (log-depth over chunks); pallas = the sequential
chunk-scan kernel.  Both match ``ref.ssd_scan_ref``.
"""
from __future__ import annotations

import jax

from repro.kernels.ssd_scan.ref import ssd_scan_ref


def ssd_scan(u, logd, Bm, Cm, *, chunk: int = 128, h0=None,
             impl: str = "auto", interpret: bool = False):
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "xla"
    if impl == "ref":
        return ssd_scan_ref(u, logd, Bm, Cm, h0=h0)
    if impl == "pallas" and h0 is None:
        from repro.kernels.ssd_scan.kernel import ssd_scan_pallas

        return ssd_scan_pallas(u, logd, Bm, Cm, chunk=chunk, interpret=interpret)
    from repro.models.ssm import ssd_chunked

    return ssd_chunked(u, logd, Bm, Cm, chunk, h0)
