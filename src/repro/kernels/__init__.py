# Pallas TPU kernels for the EAT serving hot spots (see DESIGN.md §8):
#   entropy_probe    — fused hidden x vocab -> online next-token entropy
#                      (the EAT signal itself, Eq. 5 of the paper)
#   flash_attention  — prefill/train attention, explicit-position masking
#   decode_attention — flash-decode over the KV cache (serve_step)
#   paged_attention  — page-table-native flash-decode off the paged pools
#                      (O(mapped pages) per token; bit-exact ring comparator)
#   ssd_scan         — Mamba2 SSD chunk scan (mamba2/zamba2 archs)
# Each subpackage: kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
# wrapper with XLA fallback), ref.py (pure-jnp oracle).
