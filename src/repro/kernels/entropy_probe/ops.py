"""Dispatching wrapper for the fused EAT entropy probe.

``next_token_entropy(h, w, vocab)`` returns the Shannon entropy (nats) of
softmax(h @ w)[:, :vocab] per row — Eq. (2) of the paper evaluated at the
probe position (Eq. 5 / Eq. 13).

Implementations:
  * pallas — fused streaming kernel (TPU; interpret=True in tests)
  * xla    — chunked scan over vocab tiles with the same online (m, Z, T)
             accumulators; memory-bounded, used on CPU and for the dry-run
  * ref    — naive oracle
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.kernels.entropy_probe.ref import next_token_entropy_ref

_NEG_INF = -1e30


def _xla_entropy(h, w, vocab, *, block_v=8192):
    B, d = h.shape
    Vp = w.shape[1]
    block_v = min(block_v, Vp)
    pad_v = (-Vp) % block_v
    if pad_v:
        w = jnp.pad(w, ((0, 0), (0, pad_v)))
    n_v = w.shape[1] // block_v
    hf = h.astype(jnp.float32)
    wt = jnp.moveaxis(w.reshape(d, n_v, block_v), 1, 0)  # (n_v, d, bV)

    def step(carry, inp):
        m_prev, z_prev, t_prev = carry
        w_tile, j = inp
        logits = hf @ w_tile.astype(jnp.float32)          # (B, bV)
        col = j * block_v + jnp.arange(block_v)
        valid = col < vocab
        logits = jnp.where(valid, logits, _NEG_INF)
        m_new = jnp.maximum(m_prev, logits.max(-1))
        alpha = jnp.exp(m_prev - m_new)
        e = jnp.where(valid, jnp.exp(logits - m_new[:, None]), 0.0)
        z_new = z_prev * alpha + e.sum(-1)
        t_new = t_prev * alpha + (e * jnp.where(valid, logits, 0.0)).sum(-1)
        return (m_new, z_new, t_new), None

    init = (
        jnp.full((B,), _NEG_INF, jnp.float32),
        jnp.zeros((B,), jnp.float32),
        jnp.zeros((B,), jnp.float32),
    )
    (m, z, t), _ = lax.scan(step, init, (wt, jnp.arange(n_v)))
    return m + jnp.log(z) - t / z


def next_token_entropy(
    h: jax.Array,       # (B, d) final hidden states at the probe position
    w: jax.Array,       # (d, Vp) unembedding (possibly vocab-padded)
    vocab: int,
    *,
    impl: str = "auto",
    interpret: bool = False,
) -> jax.Array:         # (B,) float32, nats
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "xla"
    if impl == "ref":
        return next_token_entropy_ref(h, w, vocab)
    if impl == "pallas":
        from repro.kernels.entropy_probe.kernel import entropy_probe_pallas

        # keep h-tile + w-tile within ~12MB VMEM
        d = h.shape[1]
        block_v = max(128, min(2048, (12 * 2**20 // max(1, d * 2)) // 128 * 128))
        return entropy_probe_pallas(h, w, vocab, block_v=block_v, interpret=interpret)
    return _xla_entropy(h, w, vocab)
