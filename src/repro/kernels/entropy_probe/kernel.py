"""Pallas TPU kernel: fused unembedding + online next-token entropy.

The EAT probe (paper §4.1) needs H(softmax(h W)) for a handful of rows but
the *full* vocabulary (paper App. H computes entropy "over the logits of the
full vocabulary", up to 256k columns).  Materializing (B, V) logits in HBM
makes the probe memory-bound: 2·B·V·2 bytes of logit traffic per
evaluation.  This kernel streams vocab tiles of W through VMEM and keeps
FlashAttention-style running accumulators

    m  = running max(logit)
    Z  = sum exp(logit - m)
    T  = sum exp(logit - m) * logit

merging tiles by rescaling, and emits  H = m + log Z - T / Z  — the
TPU-native formulation of "EAT costs one extra token" (DESIGN.md §4.2).

Grid: (B tiles, V tiles), V innermost.  Block shapes: h (bB, d) stays
resident across the V loop (index map ignores j); W tile (d, bV) streams.
bV defaults to 1024 lanes; d rides whole (assigned archs: 1024..5120 →
h tile ≤ 8x5120x4B = 160KB, W tile ≤ 5120x1024x2B = 10MB... bV is chosen
by ``ops.py`` to keep h + W tiles within a 16MB VMEM budget).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _kernel(h_ref, w_ref, o_ref, m_scr, z_scr, t_scr, *, vocab, block_v, n_v):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        z_scr[...] = jnp.zeros_like(z_scr)
        t_scr[...] = jnp.zeros_like(t_scr)

    h = h_ref[...].astype(jnp.float32)          # (bB, d)
    w = w_ref[...].astype(jnp.float32)          # (d, bV)
    logits = jax.lax.dot_general(
        h, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                            # (bB, bV)

    # mask padded vocab columns
    col = j * block_v + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
    valid = col < vocab
    logits = jnp.where(valid, logits, _NEG_INF)

    m_prev, z_prev, t_prev = m_scr[...], z_scr[...], t_scr[...]
    m_tile = jnp.max(logits, axis=-1)
    m_new = jnp.maximum(m_prev, m_tile)
    alpha = jnp.exp(m_prev - m_new)
    e = jnp.where(valid, jnp.exp(logits - m_new[:, None]), 0.0)
    z_new = z_prev * alpha + jnp.sum(e, axis=-1)
    t_new = t_prev * alpha + jnp.sum(e * jnp.where(valid, logits, 0.0), axis=-1)
    m_scr[...] = m_new
    z_scr[...] = z_new
    t_scr[...] = t_new

    @pl.when(j == n_v - 1)
    def _emit():
        m, z, t = m_scr[...], z_scr[...], t_scr[...]
        o_ref[...] = (m + jnp.log(z) - t / z).astype(o_ref.dtype)


def entropy_probe_pallas(
    h: jax.Array,      # (B, d)
    w: jax.Array,      # (d, Vp)
    vocab: int,
    *,
    block_b: int = 8,
    block_v: int = 1024,
    interpret: bool = False,
) -> jax.Array:        # (B,) float32
    B, d = h.shape
    Vp = w.shape[1]
    block_b = min(block_b, B)
    block_v = min(block_v, Vp)

    pad_b = (-B) % block_b
    if pad_b:
        h = jnp.pad(h, ((0, pad_b), (0, 0)))
    pad_v = (-Vp) % block_v
    if pad_v:
        w = jnp.pad(w, ((0, 0), (0, pad_v)))
    Bp, Vpp = h.shape[0], w.shape[1]
    n_b, n_v = Bp // block_b, Vpp // block_v

    out = pl.pallas_call(
        functools.partial(_kernel, vocab=vocab, block_v=block_v, n_v=n_v),
        grid=(n_b, n_v),
        in_specs=[
            pl.BlockSpec((block_b, d), lambda i, j: (i, 0)),
            pl.BlockSpec((d, block_v), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_b,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((Bp,), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((block_b,), jnp.float32),
            pltpu.VMEM((block_b,), jnp.float32),
            pltpu.VMEM((block_b,), jnp.float32),
        ],
        interpret=interpret,
    )(h, w)
    return out[:B]
