from repro.kernels.entropy_probe.ops import next_token_entropy  # noqa: F401
