"""Pure-jnp oracle for the EAT entropy probe (paper Eqs. 1-2, 5).

Given final hidden states h (B, d) and the (possibly padded) unembedding
matrix W (d, Vp), compute the Shannon entropy of softmax(h @ W) restricted
to the first ``vocab`` columns (padding columns are excluded — they are an
implementation artifact, not vocabulary).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def next_token_entropy_ref(h: jax.Array, w: jax.Array, vocab: int) -> jax.Array:
    """h: (B, d); w: (d, Vp); returns H (B,) in nats (float32)."""
    logits = (h.astype(jnp.float32) @ w.astype(jnp.float32))
    Vp = logits.shape[-1]
    if vocab < Vp:
        mask = jnp.arange(Vp) < vocab
        logits = jnp.where(mask, logits, -jnp.inf)
    m = jnp.max(logits, axis=-1, keepdims=True)
    z = jnp.exp(logits - m)
    Z = z.sum(-1)
    # H = m + log Z - (sum z * logits) / Z
    T = jnp.where(jnp.isfinite(logits), z * logits, 0.0).sum(-1)
    return (m[:, 0] + jnp.log(Z) - T / Z).astype(jnp.float32)
