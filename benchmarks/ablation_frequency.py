"""Paper App. G (Fig. 10): EAT under alternative evaluation frequencies.

Sub-samples the per-line trace to every-2nd / every-4th evaluation point
(≈ every-S-tokens scheduling) and checks the stopping behaviour survives."""
import numpy as np

from benchmarks.trace_harness import (
    build_trace,
    curve_auc,
    pass1_at_line,
    replay_ema_stop,
    tokens_at_line,
)


def run(out_rows: list) -> dict:
    tr = build_trace()
    rec = {}
    for stride in (1, 2, 4):
        tr2 = dict(tr)
        due = tr["due"].copy()
        # keep every stride-th due point per question
        for b in range(due.shape[1]):
            idx = np.nonzero(due[:, b])[0]
            keep = idx[::stride]
            due[:, b] = False
            due[keep, b] = True
        tr2["due"] = due
        pts = []
        for d in [2.0 ** -e for e in range(0, 20)]:
            line = replay_ema_stop(tr2, tr["eat"], alpha=0.2, delta=d)
            pts.append((tokens_at_line(tr, line).sum(), pass1_at_line(tr, line).mean()))
        pts = np.array(pts)
        rec[f"auc_stride_{stride}"] = curve_auc(pts[:, 0], pts[:, 1])
        out_rows.append((f"ablation_auc_stride_{stride}", 0.0, rec[f"auc_stride_{stride}"]))
    return rec
