"""Paper Fig. 3 / Table: Agg.Pass@1 vs total token usage — EAT (Alg. 1)
against the token-budget baseline (Alg. 2), threshold sweeps, AUC, and the
headline token-saving-at-iso-accuracy number (paper: 12-22%)."""
import numpy as np

from benchmarks.trace_harness import (
    build_trace,
    curve_auc,
    pass1_at_line,
    replay_ema_stop,
    replay_token_budget,
    tokens_at_line,
)


def sweep_eat(tr, deltas, alpha=0.2):
    pts = []
    for d in deltas:
        line = replay_ema_stop(tr, tr["eat"], alpha=alpha, delta=d)
        pts.append((tokens_at_line(tr, line).sum(), pass1_at_line(tr, line).mean()))
    return np.array(pts)


def sweep_token(tr, budgets):
    pts = []
    for T in budgets:
        line = replay_token_budget(tr, T)
        pts.append((tokens_at_line(tr, line).sum(), pass1_at_line(tr, line).mean()))
    return np.array(pts)


def _subset(tr, mask):
    sub = dict(tr)
    for k in ("answers_true", "k"):
        sub[k] = tr[k][mask]
    for k in ("n_tokens", "due", "eat", "confidence"):
        sub[k] = tr[k][:, mask]
    sub["answers"] = tr["answers"][:, :, mask]
    return sub


def _analyze(tr, deltas, budgets):
    eat_pts = sweep_eat(tr, deltas)
    tok_pts = sweep_token(tr, budgets)
    rng = (min(eat_pts[:, 0].min(), tok_pts[:, 0].min()),
           max(eat_pts[:, 0].max(), tok_pts[:, 0].max()))
    full_acc = pass1_at_line(tr, np.full(len(tr["answers_true"]), 10**9)).mean()
    tol = 0.01
    eat_ok = eat_pts[eat_pts[:, 1] >= full_acc - tol]
    tok_ok = tok_pts[tok_pts[:, 1] >= full_acc - tol]
    eat_tokens = eat_ok[:, 0].min() if len(eat_ok) else eat_pts[:, 0].max()
    tok_tokens = tok_ok[:, 0].min() if len(tok_ok) else tok_pts[:, 0].max()
    no_exit_tokens = float(tr["n_tokens"][-1].sum())
    return {
        "no_exit_tokens": no_exit_tokens,
        "saving_vs_no_exit_at_iso_acc": float(1.0 - eat_tokens / no_exit_tokens),
        "full_accuracy": float(full_acc),
        "auc_eat": curve_auc(eat_pts[:, 0], eat_pts[:, 1], t_range=rng),
        "auc_token": curve_auc(tok_pts[:, 0], tok_pts[:, 1], t_range=rng),
        "eat_tokens_at_iso_acc": float(eat_tokens),
        "token_budget_tokens_at_iso_acc": float(tok_tokens),
        "token_saving_at_iso_accuracy": float(1.0 - eat_tokens / max(tok_tokens, 1)),
        "eat_curve": eat_pts.tolist(),
        "token_curve": tok_pts.tolist(),
    }


def run(out_rows: list) -> dict:
    tr = build_trace()
    deltas = [2.0 ** -e for e in range(0, 20)]
    budgets = list(range(8, 136, 4))

    rec = {"all": _analyze(tr, deltas, budgets)}

    # paper protocol (App. I.4 / Fig. 3 GPQA columns): evaluate early exit
    # on the solvable subset — Pass@1 at the end of reasoning >= 0.8
    L = tr["answers"].shape[0]
    p1_final = pass1_at_line(tr, np.full(len(tr["answers_true"]), L - 1))
    solvable = p1_final >= 0.8
    rec["n_solvable"] = int(solvable.sum())
    if solvable.sum() >= 4:
        rec["solvable"] = _analyze(_subset(tr, solvable), deltas, budgets)
        out_rows.append(("fig3_token_saving_iso_acc_solvable", 0.0,
                         rec["solvable"]["token_saving_at_iso_accuracy"]))
        out_rows.append(("fig3_auc_eat_solvable", 0.0, rec["solvable"]["auc_eat"]))
        out_rows.append(("fig3_auc_token_solvable", 0.0, rec["solvable"]["auc_token"]))

    out_rows.append(("fig3_auc_eat", 0.0, rec["all"]["auc_eat"]))
    out_rows.append(("fig3_auc_token", 0.0, rec["all"]["auc_token"]))
    out_rows.append(("fig3_token_saving_iso_acc", 0.0,
                     rec["all"]["token_saving_at_iso_accuracy"]))
    return rec
