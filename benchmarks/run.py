# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
"""Benchmark harness — one module per paper artifact (DESIGN.md §9):

  fig1_trajectories   Pass@1 / EAT / #UA trajectories (overthinking evidence)
  fig2_variance_traces V-hat thresholding + unsolvable-question error analysis
  fig3_tradeoff       EAT vs token-budget accuracy-token curves (+AUC, saving)
  fig4_confidence     EAT vs rollout confidence (Yang et al. Eq. 16)
  fig6_ua_overhead    #UA@K sensitivity + true-cost accounting
  fig5_blackbox       proxy monitoring overlap headroom
  fig21_eat_overhead  EAT probe cost vs decode/rollout at growing context
  ablation_alpha      EMA timescale sweep (App. I.3)
  ablation_frequency  evaluation-schedule sweep (App. G)
  kernels_micro       fused entropy kernel vs naive
  roofline            dry-run roofline terms (reads artifacts/dryrun)

Run:  PYTHONPATH=src python -m benchmarks.run [--only fig3,roofline]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
import traceback

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

MODULES = [
    "fig1_trajectories",
    "fig2_variance_traces",
    "fig3_tradeoff",
    "fig4_confidence",
    "fig6_ua_overhead",
    "fig5_blackbox",
    "fig21_eat_overhead",
    "ablation_alpha",
    "ablation_frequency",
    "beyond_giveup",
    "kernels_micro",
    "roofline",
]

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated module list")
    args = ap.parse_args()
    mods = args.only.split(",") if args.only else MODULES

    os.makedirs(ART, exist_ok=True)
    rows: list[tuple[str, float, float]] = []
    results: dict = {}
    for name in mods:
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["run"])
            results[name] = mod.run(rows)
            status = "ok"
        except Exception as e:  # noqa: BLE001
            results[name] = {"error": f"{type(e).__name__}: {e}"}
            traceback.print_exc()
            status = "ERROR"
        print(f"# {name}: {status} ({time.time()-t0:.1f}s)", file=sys.stderr)

    with open(os.path.join(ART, "bench_results.json"), "w") as f:
        json.dump(results, f, indent=2, default=str)

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.2f},{derived:.6g}")


if __name__ == "__main__":
    main()
