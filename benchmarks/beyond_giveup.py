"""BEYOND-PAPER: give-up rule on unsolvable questions (paper §6 future work).

The paper's acknowledged limitation (App. I.4): on unsolvable questions EAT
never stabilizes and Alg. 1 spends the entire budget.  We compose the
stabilize-stop (Alg. 1) with a stall-detector (GiveUpStopper) and measure
the tokens saved on unsolvable questions at zero accuracy cost (they were
never going to be solved).

Unsolvable questions here = difficulty k beyond the training distribution
(the reasoner was trained on k<=6; we serve k=6 questions with the chain
corrupted by clamping the prompt difficulty field to a wrong value, so the
model's computation cannot converge — Pass@1 stays low, EAT stays noisy).
"""
import numpy as np

from benchmarks.trace_harness import build_trace, replay_ema_stop, tokens_at_line


def replay_giveup(tr, alpha=0.2, ceiling=0.05, patience=6, min_evals=4,
                  improve_tol=0.05):
    signal = tr["eat"]
    L, B = signal.shape
    m = np.zeros(B)
    v = np.zeros(B)
    n = np.zeros(B, int)
    best = np.full(B, np.inf)
    streak = np.zeros(B, int)
    exit_line = np.full(B, L - 1)
    done = np.zeros(B, bool)
    for i in range(L):
        use = tr["due"][i] & ~done
        x = signal[i]
        m_new = (1 - alpha) * m + alpha * x
        v_new = (1 - alpha) * v + alpha * (x - m_new) ** 2
        m = np.where(use, m_new, m)
        v = np.where(use, v_new, v)
        n = n + use.astype(int)
        debias = 1 - (1 - alpha) ** np.maximum(n, 1)
        dv = v / debias
        improving = dv < best * (1 - improve_tol)
        stalled = use & (dv > ceiling) & ~improving & (n >= min_evals)
        streak = np.where(stalled, streak + 1, np.where(use, 0, streak))
        best = np.where(use, np.minimum(best, dv), best)
        fire = streak >= patience
        exit_line[fire & ~done] = i
        done |= fire
    return exit_line, done


def run(out_rows: list) -> dict:
    tr = build_trace()
    L, K, B = tr["answers"].shape
    true = tr["answers_true"]
    p1 = np.stack([(tr["answers"][i] == true[None, :]).mean(0) for i in range(L)])
    unsolved = p1.max(axis=0) < 0.5
    solved = ~unsolved

    # plain Alg. 1
    line_eat = replay_ema_stop(tr, tr["eat"], alpha=0.2, delta=1e-3)
    # composed: min(stabilize-exit, give-up-exit)
    line_gu, gave_up = replay_giveup(tr)
    line_comp = np.minimum(line_eat, line_gu)

    tok_eat = tokens_at_line(tr, line_eat)
    tok_comp = tokens_at_line(tr, line_comp)

    rec = {
        "n_unsolved": int(unsolved.sum()),
        "tokens_unsolved_alg1": float(tok_eat[unsolved].sum()) if unsolved.any() else 0,
        "tokens_unsolved_composed": float(tok_comp[unsolved].sum()) if unsolved.any() else 0,
        "tokens_solved_alg1": float(tok_eat[solved].sum()),
        "tokens_solved_composed": float(tok_comp[solved].sum()),
        "gave_up_on_solved": int((gave_up & solved & (line_gu < line_eat)).sum()),
    }
    if unsolved.any():
        rec["unsolved_saving"] = 1.0 - rec["tokens_unsolved_composed"] / max(
            rec["tokens_unsolved_alg1"], 1.0)
        out_rows.append(("beyond_giveup_unsolved_saving", 0.0, rec["unsolved_saving"]))
    out_rows.append(("beyond_giveup_false_giveups", 0.0, rec["gave_up_on_solved"]))
    return rec
