"""Roofline analysis over the dry-run artifacts (deliverable g).

Per (arch x shape x mesh) record, derive the three roofline terms on
TPU v5e (197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI):

  compute_s    = flops_per_device / PEAK_FLOPS
  memory_s     = bytes_per_device / HBM_BW
  collective_s = collective_bytes_per_device / ICI_BW

All quantities are per-device (the compiled module is the per-partition
program; dividing global totals by chip count is equivalent).  The dominant
term is the bottleneck; MODEL_FLOPS = 6*N*D (dense; N_active for MoE) gives
the useful-compute ratio that catches remat/dispatch waste.

Writes artifacts/roofline.csv and the markdown table EXPERIMENTS.md embeds.
"""
from __future__ import annotations

import glob
import json
import os

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (per-chip collective budget)

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts")


def tokens_processed(shape_kind: str, seq_len: int, global_batch: int,
                     probe_len: int = 2) -> int:
    if shape_kind == "train":
        return seq_len * global_batch
    if shape_kind == "prefill":
        return seq_len * global_batch
    # decode serve_step: 1 decode token + probe positions per sequence
    return global_batch * (1 + probe_len)


def model_flops(rec: dict, shapes: dict) -> float:
    """6*N*D per step (3x forward-backward for train; 2*N*D forward-only
    for serving steps)."""
    sh = shapes[rec["shape"]]
    n_active = rec["param_count_active"]
    toks = tokens_processed(rec["kind"], sh.seq_len, sh.global_batch)
    mult = 6.0 if rec["kind"] == "train" else 2.0
    return mult * n_active * toks


def analyze(rec: dict, shapes: dict, chips: int) -> dict:
    compute_s = rec["flops_per_device"] / PEAK_FLOPS
    memory_s = rec["bytes_accessed_per_device"] / HBM_BW
    coll_s = rec["collectives"]["total"] / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec, shapes)
    hlo_global = rec["flops_per_device"] * chips
    return {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "mesh": rec["mesh"],
        "kind": rec["kind"],
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": coll_s,
        "dominant": dominant,
        "bound_s": terms[dominant],
        "model_flops": mf,
        "hlo_flops_global": hlo_global,
        "useful_ratio": mf / hlo_global if hlo_global else 0.0,
        "window": rec.get("window", 0),
    }


def load_records(out_dir: str | None = None) -> list[dict]:
    out_dir = out_dir or os.path.join(ART, "dryrun")
    recs = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def run(out_rows: list) -> dict:
    from repro.configs.base import INPUT_SHAPES

    recs = load_records()
    ok = [r for r in recs if r.get("status") == "ok"]
    rows = []
    for r in ok:
        chips = 512 if r["mesh"] == "pod2x16x16" else 256
        rows.append(analyze(r, INPUT_SHAPES, chips))

    path = os.path.join(ART, "roofline.csv")
    with open(path, "w") as f:
        f.write("arch,shape,mesh,kind,compute_s,memory_s,collective_s,"
                "dominant,bound_s,useful_ratio,window\n")
        for r in rows:
            f.write(f"{r['arch']},{r['shape']},{r['mesh']},{r['kind']},"
                    f"{r['compute_s']:.4e},{r['memory_s']:.4e},"
                    f"{r['collective_s']:.4e},{r['dominant']},{r['bound_s']:.4e},"
                    f"{r['useful_ratio']:.3f},{r['window']}\n")

    n_skip = sum(1 for r in recs if r.get("status") == "skipped")
    n_err = sum(1 for r in recs if r.get("status") == "error")
    summary = {
        "n_ok": len(ok), "n_skipped": n_skip, "n_error": n_err,
        "csv": path,
        "dominant_counts": {
            k: sum(1 for r in rows if r["dominant"] == k)
            for k in ("compute", "memory", "collective")
        },
    }
    out_rows.append(("roofline_pairs_ok", 0.0, len(ok)))
    out_rows.append(("roofline_pairs_error", 0.0, n_err))
    for r in rows:
        out_rows.append((
            f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}",
            r["bound_s"] * 1e6,
            r["useful_ratio"],
        ))
    return summary


def markdown_table(mesh: str = "pod16x16") -> str:
    from repro.configs.base import INPUT_SHAPES

    recs = [r for r in load_records() if r.get("status") == "ok" and r["mesh"] == mesh]
    rows = [analyze(r, INPUT_SHAPES, 256 if mesh == "pod16x16" else 512) for r in recs]
    lines = [
        "| arch | shape | compute (ms) | memory (ms) | collective (ms) | bottleneck | useful FLOP ratio |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in sorted(rows, key=lambda x: (x["arch"], x["shape"])):
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']*1e3:.2f} | "
            f"{r['memory_s']*1e3:.2f} | {r['collective_s']*1e3:.2f} | "
            f"**{r['dominant']}** | {r['useful_ratio']:.2f} |"
        )
    return "\n".join(lines)


if __name__ == "__main__":
    rows: list = []
    print(json.dumps(run(rows), indent=2))
    print(markdown_table())
