"""Paper Fig. 6c / Fig. 21: the cost of evaluating EAT.

Measures wall time of (i) the EAT probe (one non-committing forward of 2
probe tokens + fused entropy), (ii) one decode token, (iii) a K=8 x 4-token
rollout evaluation, at growing context lengths — the paper's claim is that
(i) ~ (ii) << (iii) and that (i) scales linearly in context (KV reuse,
§4.3).  CPU timings (relative ratios are the point; absolute numbers are
not TPU numbers)."""
import time

import jax
import jax.numpy as jnp
import numpy as np


def _time(fn, n=5):
    fn()  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n


def run(out_rows: list) -> dict:
    import os
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from examples.common import get_reasoner, make_engine

    model, params, task = get_reasoner()
    rec = {}
    for ctx_len in (64, 128, 256, 512):
        engine = make_engine(model, params, max_tokens=ctx_len)
        engine.ecfg.capacity = ctx_len + 16
        rng = np.random.default_rng(0)
        b = task.serve_batch(rng, 4)
        st = engine.start(jnp.asarray(b["prompts"]), jnp.asarray(b["prompt_len"]),
                          jax.random.PRNGKey(0))
        # fill the cache to ~ctx_len with decode steps
        while int(st.n_reasoning.max()) < ctx_len - 8:
            st = st._replace(active=jnp.ones_like(st.active))
            st = engine._decode_fn(engine.params, st)

        t_probe = _time(lambda: engine.eval_eat_now(st).block_until_ready())
        t_decode = _time(lambda: engine._decode_fn(engine.params, st).cache["cur"].block_until_ready())
        t_roll = _time(lambda: engine.rollout_answers(
            st, k=8, n_tokens=4, rng=jax.random.PRNGKey(1))[0].block_until_ready(), n=2)
        rec[f"ctx{ctx_len}"] = {
            "probe_us": t_probe * 1e6,
            "decode_us": t_decode * 1e6,
            "rollout8x4_us": t_roll * 1e6,
        }
        out_rows.append((f"fig21_probe_ctx{ctx_len}", t_probe * 1e6,
                         t_roll / max(t_probe, 1e-9)))
    ratios = [rec[k]["rollout8x4_us"] / rec[k]["probe_us"] for k in rec]
    rec["rollout_over_probe_mean"] = float(np.mean(ratios))
    return rec
