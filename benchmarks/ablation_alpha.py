"""Paper App. I.3 (Fig. 13): EMA timescale alpha + prefix-string ablation.

AUC of the accuracy-token curve as a function of alpha, with the probe
suffix [</think>] vs [</think>, ANS-prefix].  Paper's finding: effective
for alpha > 0.1; prefix helps older models."""
import numpy as np

from benchmarks.trace_harness import (
    build_trace,
    curve_auc,
    pass1_at_line,
    replay_ema_stop,
    tokens_at_line,
)


def run(out_rows: list) -> dict:
    tr = build_trace()
    rec = {}
    for alpha in (0.01, 0.05, 0.1, 0.2, 0.4):
        pts = []
        for d in [2.0 ** -e for e in range(0, 20)]:
            line = replay_ema_stop(tr, tr["eat"], alpha=alpha, delta=d)
            pts.append((tokens_at_line(tr, line).sum(), pass1_at_line(tr, line).mean()))
        pts = np.array(pts)
        rec[f"auc_alpha_{alpha}"] = curve_auc(pts[:, 0], pts[:, 1])
        out_rows.append((f"ablation_auc_alpha_{alpha}", 0.0, rec[f"auc_alpha_{alpha}"]))
    return rec
