"""Paper Fig. 5: black-box proxy monitoring — a proxy model computes EAT
from the verbal stream of a different reasoning model, and the probe time
fits inside the generator's chunk time (overlap headroom, Fig. 5b)."""
import time

import jax
import jax.numpy as jnp
import numpy as np


def run(out_rows: list) -> dict:
    import os
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from examples.common import get_reasoner, make_engine
    from repro.configs.base import get_config
    from repro.core.eat import make_probe
    from repro.core.monitor import ReasoningMonitor
    from repro.core.stopping import EATStopper
    from repro.data.synthetic import ChainTask, Tokens
    from repro.models import Model
    from repro.serving.proxy import ProxyMonitor

    model, params, task = get_reasoner()
    engine = make_engine(model, params, max_tokens=64)

    # SMALLER proxy (the paper's 1.5B-monitors-70B shape at toy scale):
    # the timing claim (Fig. 5b: probe hides behind generation) is what we
    # measure here; proxy signal QUALITY with a trained proxy is exercised
    # in examples/blackbox_proxy.py
    pcfg = get_config("tiny")
    proxy_model = Model(pcfg, attn_impl="xla")
    proxy_params = proxy_model.init(jax.random.PRNGKey(1))
    mon = ReasoningMonitor(
        stopper=EATStopper(alpha=0.2, delta=1e-3),
        probe=make_probe(Tokens.END_THINK, (Tokens.ANS,)),
        newline_id=Tokens.NEWLINE,
    )
    proxy = ProxyMonitor(model=proxy_model, params=proxy_params, monitor=mon,
                         capacity=128)

    rng = np.random.default_rng(5)
    b = task.serve_batch(rng, 4)
    st = engine.start(jnp.asarray(b["prompts"]), jnp.asarray(b["prompt_len"]),
                      jax.random.PRNGKey(0))
    pst = proxy.start(jnp.asarray(b["prompts"]), jnp.asarray(b["prompt_len"]))

    CHUNK = 8
    gen_times = []
    for _ in range(5):
        t0 = time.perf_counter()
        buf = []
        for _ in range(CHUNK):
            st = st._replace(active=jnp.ones_like(st.active))
            st = engine._decode_fn(engine.params, st)
            buf.append(np.asarray(st.last_token))
        gen_times.append(time.perf_counter() - t0)
        pst = proxy.observe_chunk(pst, jnp.asarray(np.stack(buf, 1)))

    gen_ms = float(np.mean(gen_times) * 1e3)
    probe_ms = float(np.mean(pst["probe_seconds"]) * 1e3)
    rec = {
        "chunk_tokens": CHUNK,
        "generator_chunk_ms": gen_ms,
        "proxy_probe_ms": probe_ms,
        "overlap_headroom": gen_ms / max(probe_ms, 1e-9),
        "proxy_eat_finite": bool(np.isfinite(np.asarray(pst["last_eat"])).all()),
    }
    out_rows.append(("fig5_overlap_headroom", probe_ms * 1e3, rec["overlap_headroom"]))
    return rec
