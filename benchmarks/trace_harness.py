"""Shared evaluation harness: the paper's App. H offline protocol.

Generate ONE long reasoning chain per question with the trained synthetic
reasoner and record, at every paragraph break: token count, EAT, K forced
rollout answers, and the 5-token greedy confidence (Eq. 16).  Every
benchmark figure then *replays* this trace against different stopping rules
— "saving it once to disk and replaying it offline to compute metrics at
arbitrary exit thresholds without re-querying the model" (App. H).

Cached at artifacts/trace.npz.
"""
from __future__ import annotations

import os
import sys

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from examples.common import get_reasoner, make_engine  # noqa: E402
from repro.data.synthetic import ChainTask  # noqa: E402

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts")
TRACE = os.path.join(ART, "trace.npz")

N_QUESTIONS = 32
ROLLOUT_K = 16
MAX_TOKENS = 128


def build_trace(n_questions=N_QUESTIONS, rollout_k=ROLLOUT_K,
                max_tokens=MAX_TOKENS, seed=0, force=False) -> dict:
    if os.path.exists(TRACE) and not force:
        with np.load(TRACE) as z:
            return dict(z)
    model, params, task = get_reasoner()
    engine = make_engine(model, params, max_tokens=max_tokens)
    rng = np.random.default_rng(seed)
    batch = task.serve_batch(rng, n_questions)
    st = engine.start(jnp.asarray(batch["prompts"]), jnp.asarray(batch["prompt_len"]),
                      jax.random.PRNGKey(seed))
    st, trace = engine.reason_with_trace(
        st, max_tokens=max_tokens, rollout_k=rollout_k, rollout_len=4,
        answer_extract=ChainTask.extract_answer, confidence_len=5,
    )
    out = {
        "answers_true": batch["answers"],
        "k": batch["k"],
        "n_tokens": np.stack([r["n_tokens"] for r in trace]),       # (L, B)
        "due": np.stack([r["due"] for r in trace]),                 # (L, B)
        "eat": np.stack([r["eat"] for r in trace]),                 # (L, B)
        "answers": np.stack([r["answers"] for r in trace]),         # (L, K, B)
        "confidence": np.stack([r["confidence"] for r in trace]),   # (L, B)
    }
    os.makedirs(ART, exist_ok=True)
    np.savez(TRACE, **out)
    return out


# ----------------------------------------------------------------- replay


def pass1_at_line(tr: dict, line: np.ndarray) -> np.ndarray:
    """Pass@1(Avg@K) per question at (per-question) line indices."""
    L, K, B = tr["answers"].shape
    li = np.clip(line, 0, L - 1)
    ans = tr["answers"][li, :, np.arange(B)]        # (B, K)
    return (ans == tr["answers_true"][:, None]).mean(axis=1)


def tokens_at_line(tr: dict, line: np.ndarray) -> np.ndarray:
    L, B = tr["n_tokens"].shape
    li = np.clip(line, 0, L - 1)
    return tr["n_tokens"][li, np.arange(B)]


def replay_ema_stop(tr: dict, signal: np.ndarray, alpha: float, delta: float,
                    min_evals: int = 2) -> np.ndarray:
    """Replay Alg. 1 (EMA variance threshold, de-biased) over a per-line
    signal; returns per-question exit line index (L-1 if never)."""
    L, B = signal.shape
    m = np.zeros(B)
    v = np.zeros(B)
    n = np.zeros(B, int)
    exit_line = np.full(B, L - 1)
    done = np.zeros(B, bool)
    for i in range(L):
        use = tr["due"][i] & ~done
        x = signal[i]
        m_new = (1 - alpha) * m + alpha * x
        v_new = (1 - alpha) * v + alpha * (x - m_new) ** 2
        m = np.where(use, m_new, m)
        v = np.where(use, v_new, v)
        n = n + use.astype(int)
        debias = 1 - (1 - alpha) ** np.maximum(n, 1)
        fire = use & (n >= min_evals) & (v / debias < delta)
        exit_line[fire & ~done] = i
        done |= fire
    return exit_line


def replay_token_budget(tr: dict, budget: int) -> np.ndarray:
    L, B = tr["n_tokens"].shape
    exit_line = np.full(B, L - 1)
    for b in range(B):
        hits = np.nonzero(tr["n_tokens"][:, b] >= budget)[0]
        if len(hits):
            exit_line[b] = hits[0]
    return exit_line


def replay_ua_stop(tr: dict, k: int, max_unique: int, rng=None) -> np.ndarray:
    """#UA@K (Alg. 3): exit when #unique among k of the K recorded rollouts
    <= max_unique."""
    L, K, B = tr["answers"].shape
    rng = rng or np.random.default_rng(0)
    sel = rng.choice(K, size=min(k, K), replace=False)
    exit_line = np.full(B, L - 1)
    done = np.zeros(B, bool)
    for i in range(L):
        ans = tr["answers"][i][sel]               # (k, B)
        uniq = np.array([len(set(ans[:, b])) for b in range(B)])
        fire = tr["due"][i] & (uniq <= max_unique) & ~done
        exit_line[fire] = i
        done |= fire
    return exit_line


def curve_auc(tokens: np.ndarray, acc: np.ndarray,
              t_range: tuple | None = None) -> float:
    """Area under the accuracy-vs-tokens curve, normalized over a token
    range (larger = more efficient).  Pass a common ``t_range`` when
    comparing methods (curves are step-interpolated and clamped to their
    endpoint values outside their observed range)."""
    order = np.argsort(tokens)
    t, a = np.asarray(tokens, float)[order], np.asarray(acc, float)[order]
    lo, hi = t_range if t_range is not None else (t[0], t[-1])
    if hi == lo:
        return float(a.mean())
    grid = np.linspace(lo, hi, 256)
    vals = np.interp(grid, t, a, left=a[0], right=a[-1])
    return float(np.trapezoid(vals, grid) / (hi - lo))
