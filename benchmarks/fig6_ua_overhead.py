"""Paper Fig. 6 (+Fig. 19): #UA@K — performance-overhead trade-off.

(a) #UA@K needs K >= 16 to match EAT's accuracy-token curve;
(b) counting the rollout tokens, its true cost is far above EAT;
(c) per-evaluation wall time: K rollouts of 4 tokens vs one EAT probe.
"""
import time

import numpy as np

from benchmarks.trace_harness import (
    build_trace,
    curve_auc,
    pass1_at_line,
    replay_ema_stop,
    replay_ua_stop,
    tokens_at_line,
)


def run(out_rows: list) -> dict:
    tr = build_trace()
    L, K, B = tr["answers"].shape
    rec = {}

    eat_pts = []
    for d in [2.0 ** -e for e in range(0, 20)]:
        line = replay_ema_stop(tr, tr["eat"], alpha=0.2, delta=d)
        eat_pts.append((tokens_at_line(tr, line).sum(), pass1_at_line(tr, line).mean()))
    eat_pts = np.array(eat_pts)
    rec["auc_eat"] = curve_auc(eat_pts[:, 0], eat_pts[:, 1])

    rollout_len = 4
    for k in (4, 8, 16):
        pts, pts_true = [], []
        for max_u in (1, 2, 3):
            line = replay_ua_stop(tr, k=k, max_unique=max_u)
            toks = tokens_at_line(tr, line)
            acc = pass1_at_line(tr, line).mean()
            # true cost includes K rollouts of rollout_len at every due line
            n_evals = np.array([tr["due"][: line[b] + 1, b].sum() for b in range(B)])
            true_cost = toks.sum() + (n_evals * k * rollout_len).sum()
            pts.append((toks.sum(), acc))
            pts_true.append((true_cost, acc))
        pts = np.array(pts)
        rec[f"ua_k{k}_acc_at_u1"] = float(pts[0, 1])
        rec[f"ua_k{k}_reasoning_tokens"] = float(pts[0, 0])
        rec[f"ua_k{k}_true_tokens"] = float(np.array(pts_true)[0, 0])
        out_rows.append((f"fig6_ua_k{k}_true_over_reasoning", 0.0,
                         rec[f"ua_k{k}_true_tokens"] / max(rec[f"ua_k{k}_reasoning_tokens"], 1)))

    # EAT true cost: + len(probe)=2 positions per evaluation (prefilled in
    # parallel ~ 1 decode-token equivalent, paper §4.3)
    line = replay_ema_stop(tr, tr["eat"], alpha=0.2, delta=1e-3)
    n_evals = np.array([tr["due"][: line[b] + 1, b].sum() for b in range(B)])
    rec["eat_true_tokens"] = float(tokens_at_line(tr, line).sum() + n_evals.sum())
    out_rows.append(("fig6_eat_true_tokens", 0.0, rec["eat_true_tokens"]))
    return rec
