"""Engine decode throughput: per-token host loop vs device-resident chunks,
the data-parallel serve() scaling sweep, and the ring-vs-paged KV cache A/B.

The per-token path dispatches one jitted step per token and syncs the host
twice per iteration (``active.any()``, ``n_reasoning.max()``); the chunked
path runs a ``lax.while_loop`` of up to ``chunk_len`` monitored steps per
dispatch and syncs once per chunk.  Same tiny model, same sampler, same
EAT monitor — the measured delta is pure dispatch + sync overhead, i.e.
exactly what the probe-kernel work cannot recover from a host-bound loop.

``--scaling`` runs the continuous-batching ``serve()`` loop on (N x 1)
data-parallel meshes of 1/2/4/8 simulated host devices (one subprocess per
device count — the device count is fixed at process start) and emits
``BENCH_serve_scaling.json`` — throughput plus per-request latency
p50/p95/p99 — so the perf trajectory accumulates per PR.  ``--overlap on``
serves through the double-buffered pipeline (``serve(overlap=True)``): one
blocking snapshot read per chunk boundary instead of one sync per
host-facing scalar, which is exactly the host overhead the sync sweep's
scaling cliff is made of.  On one physical CPU the simulated sweep
measures sharding/dispatch overhead, not real speedup; on real chips the
same harness measures both.

``--cache {ring,paged,both}`` runs the mixed-exit-length serving workload
(temperature sampling — sequences exit via a naturally sampled </think> at
geometrically distributed lengths, or at the budget) under a FIXED physical
KV-slot budget.  The ring spends it as ``batch * capacity`` dense slots, so
the batch-lifetime capacity rule caps how many requests one batch may
legally serve; the paged cache spends the same slots as a shared page pool,
reclaims an exiting request's pages mid-batch, and admits the whole queue.
``both`` emits ``artifacts/BENCH_paged_cache.json`` (requests-served and
tok/s per backend — docs/serving.md §Choosing a cache backend).

``--attn`` runs the dense-gather vs page-native decode-attention A/B
(docs/serving.md §Page-native attention): per-token decode cost at a FIXED
occupancy (live mapped slots per row) across a logical-capacity sweep — the
``num_pages`` pool grows ring-equivalently with capacity while the live
tokens do not.  The gather path materializes the (B, capacity) logical view
every step, so its per-token cost scales with the sweep; the page-native
path reads only the mapped pages through the compacted page list, so its
cost stays flat at equal occupancy.  Emits
``artifacts/BENCH_paged_attn.json``.

``--monitor proxy`` runs the self-EAT vs black-box proxy-EAT serving A/B
(docs/serving.md §Black-box monitoring) on a mixed-exit greedy workload
(delta auto-calibrated to the median first-evaluation variance, so part of
the queue exits via EAT and part runs to budget).  A same-params proxy
pins tokens-saved parity with self-EAT (per-request exit steps within ±1 —
bit-equal in practice) and the generator-side probe-program count (0, the
black-box contract); the probe-FLOPs ratio of a genuinely small proxy
(``--proxy-arch``, default tiny-proxy) vs the generator quantifies the
monitoring discount.  Emits ``artifacts/BENCH_proxy_serve.json``.

Run:  PYTHONPATH=src python benchmarks/engine_throughput.py
      [--batch 8] [--budget 96] [--chunks 1 8 32] [--out artifacts/...json]
      [--scaling] [--devices-list 1 2 4 8] [--overlap on]
      [--cache both] [--requests 32] [--page-size 16]
      [--monitor proxy] [--proxy-arch tiny-proxy]
"""
import argparse
import json
import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.core.eat import make_probe
from repro.core.monitor import ReasoningMonitor
from repro.core.stopping import EATStopper
from repro.data.synthetic import ChainTask, Tokens
from repro.models import Model
from repro.serving.cache import CacheConfig, page_align
from repro.serving.engine import EngineConfig, ReasoningEngine
from repro.serving.sampler import SamplerConfig


def write_json(path: str, rec: dict) -> None:
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        json.dump(rec, f, indent=2)


def build_engine(budget: int, ctx=None, capacity=None,
                 cache: CacheConfig | None = None) -> ReasoningEngine:
    cfg = get_config("tiny")
    model = Model(cfg, attn_impl="xla") if ctx is None else \
        Model(cfg, ctx, attn_impl="xla")
    params = model.init(jax.random.PRNGKey(0))
    ecfg = EngineConfig(
        max_reasoning_tokens=budget,
        capacity=capacity if capacity is not None else max(256, budget + 64),
        pad_id=Tokens.PAD, end_think_id=Tokens.END_THINK,
        newline_id=Tokens.NEWLINE, eos_id=Tokens.EOS,
        sampler=SamplerConfig(temperature=1.0, top_p=0.95),
        cache=cache or CacheConfig(),
    )
    # delta=0 -> the monitor runs (probe + EMA at every paragraph break)
    # but never fires, so both paths decode the full budget: equal work.
    monitor = ReasoningMonitor(
        stopper=EATStopper(alpha=0.2, delta=0.0),
        probe=make_probe(Tokens.END_THINK, (Tokens.ANS,)),
        newline_id=Tokens.NEWLINE,
    )
    return ReasoningEngine(model, params, ecfg, monitor)


def measure(run, engine, batch, budget: int, reps: int) -> tuple[float, int]:
    """Median wall seconds + tokens generated for ``run(state)``."""
    times, tokens = [], 0
    for rep in range(reps + 1):        # rep 0 = compile warmup
        st = engine.start(jnp.asarray(batch["prompts"]),
                          jnp.asarray(batch["prompt_len"]),
                          jax.random.PRNGKey(100 + rep))
        jax.block_until_ready(st.cache["pos"])
        t0 = time.perf_counter()
        st = run(st)
        jax.block_until_ready(st.out_tokens)
        if rep:
            times.append(time.perf_counter() - t0)
            tokens = int(np.asarray(st.n_reasoning).sum())
    return float(np.median(times)), tokens


def run_serve_child(devices: int, batch_per_dev: int, budget: int,
                    reps: int, overlap: bool = False) -> dict:
    """One point of the DP scaling sweep, inside a process whose device
    count was fixed by XLA_FLAGS: weak scaling — global batch =
    ``batch_per_dev * devices`` slots on an (N x 1) data-parallel mesh,
    2x-oversubscribed request queue through ``serve()``.  ``overlap``
    runs the double-buffered pipeline (one host read per boundary instead
    of one per host-facing scalar); per-request latency percentiles come
    from the ``latency_s`` each result now carries."""
    from repro.launch.mesh import make_device_ctx
    from repro.serving.scheduler import SlotScheduler

    assert len(jax.devices()) == devices, jax.devices()
    B = batch_per_dev * devices
    n_req = 2 * B
    batch = ChainTask().serve_batch(np.random.default_rng(0), n_req)
    capacity = SlotScheduler.required_capacity(
        batch["prompts"].shape[1], n_req, B, budget
    )
    if overlap:
        # the overlapped loop's ring guard adds one in-flight chunk to its
        # host-mirror pointer estimate — give it that headroom
        capacity += EngineConfig.chunk_len
    engine = build_engine(budget, ctx=make_device_ctx(devices, 1),
                          capacity=capacity)

    times, tokens, lat = [], 0, []
    for rep in range(reps + 1):        # rep 0 = compile warmup
        t0 = time.perf_counter()
        results = engine.serve(batch["prompts"], batch["prompt_len"],
                               jax.random.PRNGKey(100 + rep), batch_size=B,
                               max_tokens=budget, overlap=overlap)
        if rep:
            times.append(time.perf_counter() - t0)
            tokens = int(sum(r["n_reasoning"] for r in results))
            lat += [r["latency_s"] for r in results]
    sec = float(np.median(times))
    p50, p95, p99 = (float(np.percentile(lat, q)) for q in (50, 95, 99))
    return {"devices": devices, "batch": B, "requests": n_req,
            "budget": budget, "overlap": overlap, "seconds": sec,
            "tokens": tokens, "tokens_per_s": tokens / sec,
            "latency_s": {"p50": p50, "p95": p95, "p99": p99}}


def run_cache_bench(args) -> dict:
    """Ring vs paged serve() under ONE physical KV-slot budget.

    Workload: ``--requests`` prompts through ``--batch`` slots, temperature
    sampling (mixed exit lengths: natural </think> at geometric lengths or
    the budget).  The physical budget is ``batch * C_ring`` dense slots
    where ``C_ring = S + 2*budget`` — enough ring capacity for roughly one
    recycled cohort.  The ring may only admit the queue prefix whose
    batch-lifetime fits that capacity (``required_capacity``); the paged
    backend spends the same slots as a shared pool and serves everything,
    reusing exited requests' pages mid-batch.
    """
    from repro.serving.scheduler import SlotScheduler

    task = ChainTask()
    B, budget, ps = args.batch, args.budget, args.page_size
    n_req = args.requests or 4 * B
    batch = task.serve_batch(np.random.default_rng(0), n_req)
    S = batch["prompts"].shape[1]
    C_ring = page_align(S + 2 * budget, ps)
    phys_slots = B * C_ring                           # THE memory budget

    # ring: largest queue prefix whose batch lifetime fits C_ring
    k_ring = n_req
    while k_ring > 1 and SlotScheduler.required_capacity(
            S, k_ring, B, budget) > C_ring:
        k_ring -= 1
    # paged: logical capacity covers the whole queue (int32 metadata —
    # cheap); the PHYSICAL pool is the same phys_slots budget
    C_log = page_align(SlotScheduler.required_capacity(S, n_req, B, budget),
                       ps)
    variants = {
        "ring": dict(n=k_ring, capacity=C_ring, cache=CacheConfig()),
        "paged": dict(n=n_req, capacity=C_log,
                      cache=CacheConfig(kind="paged", page_size=ps,
                                        num_pages=phys_slots // ps + 1)),
    }

    rec = {"workload": "mixed_exit_serve", "batch": B, "budget": budget,
           "requests_queued": n_req, "physical_kv_slots": phys_slots,
           "page_size": ps}
    for kind in (("ring", "paged") if args.cache == "both" else (args.cache,)):
        v = variants[kind]
        engine = build_engine(budget, capacity=v["capacity"], cache=v["cache"])
        times, tokens = [], 0
        for rep in range(args.reps + 1):              # rep 0 = warmup
            t0 = time.perf_counter()
            # ONE key for every rep: temperature sampling means the exit
            # lengths (and so the token count) depend on the key — a
            # per-rep key would divide one rep's tokens by another rep's
            # median seconds
            results = engine.serve(
                batch["prompts"][:v["n"]], batch["prompt_len"][:v["n"]],
                jax.random.PRNGKey(100), batch_size=B,
                max_tokens=budget,
            )
            if rep:
                times.append(time.perf_counter() - t0)
                tokens = int(sum(r["n_reasoning"] for r in results))
        sec = float(np.median(times))
        rec[kind] = {
            "requests_served": v["n"], "capacity": v["capacity"],
            "seconds": sec, "tokens": tokens, "tokens_per_s": tokens / sec,
        }
        print(f"{kind:>6s}: served {v['n']:3d}/{n_req} requests  "
              f"{tokens:6d} tok  {tokens / sec:8.0f} tok/s", flush=True)

    if args.cache == "both":
        rec["paged_admits_more"] = (rec["paged"]["requests_served"]
                                    > rec["ring"]["requests_served"])
        path = args.out or os.path.join(
            os.path.dirname(__file__), "..", "artifacts",
            "BENCH_paged_cache.json")
        write_json(path, rec)
        print(f"wrote {os.path.normpath(path)}")
    return rec


def run_attn_bench(args) -> dict:
    """Dense-gather vs page-native decode attention: per-token cost vs
    logical capacity at fixed occupancy.

    For each capacity in the sweep, both engines hold the SAME live state:
    ``--attn-occupancy`` mapped slots per row of a ``--batch``-row paged
    cache whose physical pool is sized to that occupancy and HELD FIXED —
    the sweep grows only the logical capacity (the batch-lifetime bound a
    longer request queue needs; int32 metadata plus, for the gather path,
    the materialized logical view).  The timed program is the DONATING
    unmonitored ``decode_chunk``
    — the actual serving hot path, pools aliased in place (the non-donating
    ``decode_step`` would copy the whole pool every call and swamp the
    attention delta) — with identical sampling/bookkeeping either way, so
    the measured delta is the attention read: gather cost ~ capacity,
    page-native cost ~ mapped pages.  ``end_think_id`` is parked on an
    unreachable id so every row decodes the full chunk.
    """
    from repro.serving.cache import alloc_paged_template
    from repro.serving.scheduler import PageAllocator

    task = ChainTask()
    B, ps = args.batch, args.page_size
    batch = task.serve_batch(np.random.default_rng(0), B)
    S = batch["prompts"].shape[1]
    # occupancy must cover the prompt + every decoded token of the timing
    # run (writing into an unmapped page would attend trash), and stays
    # FIXED across the capacity sweep
    decoded = (args.reps + 1) * args.attn_iters
    occ = page_align(max(args.attn_occupancy, S + decoded + ps), ps)
    too_small = [c for c in args.attn_capacities if page_align(c, ps) < occ]
    if too_small:
        # a capacity below the occupancy would silently clamp the mapped
        # span and wrap the ring mid-timing — the fixed-occupancy premise
        # (and so the whole A/B) would be false for those points
        raise SystemExit(
            f"--attn-capacities {too_small} are smaller than the {occ}-slot "
            f"occupancy this timing run needs (prompt {S} + "
            f"(reps+1)*iters {decoded} decoded tokens, page-aligned); "
            f"raise them or lower --reps / --attn-iters / --attn-occupancy")

    def decode_state(engine):
        """The serve()-paged setup at a pinned occupancy: prompt prefill,
        ``occ`` slots of mapped pages per row, packed into the pool."""
        ecfg = engine.ecfg
        ccfg = ecfg.cache
        C_log = page_align(ecfg.capacity, ps)
        n_blocks = C_log // ps
        # pool sized to the LIVE tokens, constant across the sweep — the
        # whole point of paging: physical footprint tracks occupancy, not
        # the logical bound
        num_pages = B * (occ // ps) + 1
        alloc = PageAllocator(num_pages, ps, n_blocks, B)
        st = engine.start(jnp.asarray(batch["prompts"]),
                          jnp.asarray(batch["prompt_len"]),
                          jax.random.PRNGKey(0), capacity=page_align(S, ps))
        for row in range(B):
            alloc.ensure(row, 0, occ - 1)
        template = alloc_paged_template(
            engine.model.cfg, B, C_log, ps, num_pages, alloc=alloc,
            native=ccfg.attn_impl != "gather")
        st = st._replace(cache=engine.executor.pack_paged(
            template, st.cache, alloc.table))
        return st, num_pages

    def time_decode(engine, st) -> float:
        iters = args.attn_iters
        budget = jnp.asarray(1 << 30, jnp.int32)
        chunk = jnp.asarray(iters, jnp.int32)
        # decode_chunk DONATES st: continue from the returned state
        st = engine.executor.decode_chunk(engine.params, st, budget, chunk,
                                          use_monitor=False)    # warmup
        jax.block_until_ready(st.out_tokens)
        times = []
        for _ in range(args.reps):
            t0 = time.perf_counter()
            st = engine.executor.decode_chunk(engine.params, st, budget,
                                              chunk, use_monitor=False)
            jax.block_until_ready(st.out_tokens)
            times.append((time.perf_counter() - t0) / iters)
        return float(np.median(times))

    points = []
    for cap in args.attn_capacities:
        point = {"capacity": int(page_align(cap, ps))}
        for label, impl in (("gather", "gather"), ("page_native", "xla")):
            engine = build_engine(
                args.budget, capacity=cap,
                cache=CacheConfig(kind="paged", page_size=ps,
                                  attn_impl=impl))
            # no row may stop mid-chunk: park </think> on an unreachable id
            # (programs are built lazily, after this)
            engine.ecfg.end_think_id = -7
            st, num_pages = decode_state(engine)
            point["num_pages"] = num_pages
            point[label + "_s_per_tok"] = time_decode(engine, st)
        points.append(point)
        print(f"capacity={point['capacity']:5d}  pages={point['num_pages']:5d}  "
              f"gather={point['gather_s_per_tok'] * 1e3:7.2f} ms/tok  "
              f"page-native={point['page_native_s_per_tok'] * 1e3:7.2f} ms/tok",
              flush=True)

    g = [p["gather_s_per_tok"] for p in points]
    n = [p["page_native_s_per_tok"] for p in points]
    rec = {
        "workload": "decode_cost_vs_logical_capacity", "batch": B,
        "page_size": ps, "occupancy_slots": int(occ),
        "capacities": [p["capacity"] for p in points], "points": points,
        # the acceptance shape: gather grows across the sweep, page-native
        # stays flat at equal occupancy
        "gather_cost_growth": g[-1] / g[0],
        "page_native_cost_growth": n[-1] / n[0],
        "page_native_flat": n[-1] / n[0] < (g[-1] / g[0]) / 2,
    }
    path = args.out or os.path.join(
        os.path.dirname(__file__), "..", "artifacts",
        "BENCH_paged_attn.json")
    write_json(path, rec)
    print(f"gather grows {rec['gather_cost_growth']:.2f}x over the sweep; "
          f"page-native {rec['page_native_cost_growth']:.2f}x "
          f"(flat={rec['page_native_flat']})")
    print(f"wrote {os.path.normpath(path)}")
    return rec


def run_proxy_bench(args) -> dict:
    """Self-EAT vs black-box proxy-EAT serving A/B on one mixed-exit greedy
    workload (paper Fig. 5 through the serving stack).

    A same-params proxy must save the same tokens as self-EAT (the exit
    decisions are bit-equal under greedy sampling — tests/test_proxy_serve
    pins the exact equality; the artifact reports the ±1-step parity
    check), while the generator executor builds zero probe programs.  The
    probe-FLOPs ratio of the small ``--proxy-arch`` model vs the generator
    is the black-box monitoring discount: what an EAT evaluation costs when
    a cheap local model pays for it instead of the big one.
    """
    from repro.core.eat import eval_eat
    from repro.serving.cache import alloc_cache
    from repro.serving.proxy import ProxyConfig
    from repro.serving.scheduler import SlotScheduler
    from repro.utils.jax_compat import cost_analysis_dict

    task = ChainTask()
    B, budget = args.batch, args.budget
    n_req = args.requests or 2 * B
    batch = task.serve_batch(np.random.default_rng(0), n_req)
    S = batch["prompts"].shape[1]
    # one extra budget of ring slack: the proxy-mode generator decodes to
    # the chunk boundary before a retract lands, so its ring pointer can
    # outrun the self-EAT run by up to chunk_len per exit
    capacity = SlotScheduler.required_capacity(S, n_req, B, budget) + budget

    cfg = get_config("tiny")
    model = Model(cfg, attn_impl="xla")
    params = model.init(jax.random.PRNGKey(0))
    probe = make_probe(Tokens.END_THINK, (Tokens.ANS,))

    def make(delta, proxy=None):
        ecfg = EngineConfig(
            max_reasoning_tokens=budget, capacity=capacity,
            pad_id=Tokens.PAD, end_think_id=Tokens.END_THINK,
            newline_id=Tokens.NEWLINE, eos_id=Tokens.EOS, chunk_len=8,
            sampler=SamplerConfig(greedy=True),
        )
        monitor = ReasoningMonitor(
            stopper=EATStopper(alpha=0.2, delta=delta), probe=probe,
            schedule="every_n", every_n=8, min_evals=1,
        )
        return ReasoningEngine(model, params, ecfg, monitor, proxy=proxy)

    # calibrate delta to the median of each request's LOWEST EMA variance
    # (a delta=0 dry run records the full trajectories): requests whose
    # variance dips below it exit via EAT, the rest run to budget or end
    # naturally — a genuinely mixed-exit workload, still greedy (=>
    # deterministic, parity-comparable between monitor tiers)
    cal = make(0.0).serve(batch["prompts"], batch["prompt_len"],
                          jax.random.PRNGKey(100), batch_size=B,
                          max_tokens=budget, record_trace=True)
    min_vars = [min((v for (_, e, v) in r["eat_trace"] if e >= 1),
                    default=None) for r in cal]
    delta = float(np.median([v for v in min_vars if v is not None]))

    def run(proxy):
        engine = make(delta, proxy=proxy)
        times = []
        for rep in range(args.reps + 1):              # rep 0 = warmup
            t0 = time.perf_counter()
            results = engine.serve(batch["prompts"], batch["prompt_len"],
                                   jax.random.PRNGKey(100), batch_size=B,
                                   max_tokens=budget)
            if rep:
                times.append(time.perf_counter() - t0)
        sec = float(np.median(times))
        steps = [r["n_reasoning"] for r in results]
        reasons = {}
        for r in results:
            reasons[r["exit_reason"]] = reasons.get(r["exit_reason"], 0) + 1
        return engine, {
            "seconds": sec, "tokens": int(sum(steps)),
            "tokens_per_s": sum(steps) / sec,
            "tokens_saved_vs_budget": int(n_req * budget - sum(steps)),
            "exit_steps": steps, "exit_reasons": reasons,
        }

    eng_self, rec_self = run(None)
    eng_proxy, rec_proxy = run(ProxyConfig(model=model, params=params))
    step_deltas = [abs(a - b) for a, b in zip(rec_self["exit_steps"],
                                              rec_proxy["exit_steps"])]
    gen_probe_programs = len(
        [k for k in eng_proxy.executor._programs
         if k[0] == "probe" or (k[0] == "chunk" and k[2])])

    def probe_flops(cfg_name):
        c = get_config(cfg_name)
        m = Model(c, attn_impl="xla")
        p = m.init(jax.random.PRNGKey(0))
        cache = alloc_cache(c, B, capacity)
        fn = jax.jit(lambda pp, cc, np_: eval_eat(m, pp, cc, probe, np_))
        comp = fn.lower(p, cache, jnp.zeros((B,), jnp.int32)).compile()
        return float(cost_analysis_dict(comp).get("flops", 0.0))

    f_self, f_small = probe_flops("tiny"), probe_flops(args.proxy_arch)
    rec = {
        "workload": "mixed_exit_proxy_serve", "batch": B, "budget": budget,
        "requests": n_req, "delta": delta,
        "self": rec_self, "proxy": rec_proxy,
        "exit_step_max_delta": int(max(step_deltas, default=0)),
        "tokens_saved_parity": max(step_deltas, default=0) <= 1,
        "generator_probe_programs": gen_probe_programs,
        "probe_flops": {"generator": f_self, "proxy_arch": args.proxy_arch,
                        "proxy": f_small,
                        "ratio": f_small / f_self if f_self else None},
    }
    for mode in ("self", "proxy"):
        r = rec[mode]
        print(f"{mode:>6s}: {r['tokens']:6d} tok "
              f"(saved {r['tokens_saved_vs_budget']:5d} vs budget)  "
              f"{r['tokens_per_s']:8.0f} tok/s  exits={r['exit_reasons']}",
              flush=True)
    ratio = rec["probe_flops"]["ratio"]
    print(f"exit-step max delta: {rec['exit_step_max_delta']}  "
          f"generator probe programs: {gen_probe_programs}  "
          f"probe-FLOPs ratio ({args.proxy_arch}/tiny): "
          + (f"{ratio:.3f}" if ratio is not None else "n/a"))
    path = args.out or os.path.join(
        os.path.dirname(__file__), "..", "artifacts",
        "BENCH_proxy_serve.json")
    write_json(path, rec)
    print(f"wrote {os.path.normpath(path)}")
    return rec


def run_scaling_sweep(args) -> dict:
    """Fan the sweep out one subprocess per (device count, loop mode) and
    collect ``BENCH_serve_scaling.json``.  The simulated device count is
    fixed at jax import, hence the subprocesses.  With ``--overlap on``
    every device count runs BOTH loops — the synchronous boundary loop and
    the double-buffered pipeline — so the artifact carries the A/B
    side by side instead of a lone overlap curve with no reference."""
    def child(n, overlap_mode):
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
        env.setdefault("JAX_PLATFORMS", "cpu")
        env["PYTHONPATH"] = os.pathsep.join(
            [os.path.join(os.path.dirname(__file__), "..", "src")]
            + [p for p in env.get("PYTHONPATH", "").split(os.pathsep) if p]
        )
        cmd = [sys.executable, os.path.abspath(__file__), "--serve-child",
               str(n), "--batch", str(args.batch),
               "--budget", str(args.budget), "--reps", str(args.reps),
               "--overlap", overlap_mode]
        r = subprocess.run(cmd, capture_output=True, text=True, env=env,
                           timeout=1200)
        if r.returncode != 0:
            raise RuntimeError(f"scaling child devices={n} failed:\n"
                               f"{r.stdout}\n{r.stderr}")
        line = [ln for ln in r.stdout.splitlines()
                if ln.startswith("SCALING_RESULT ")][-1]
        return json.loads(line[len("SCALING_RESULT "):])

    modes = ["off", "on"] if args.overlap == "on" else ["off"]
    points = []
    for n in args.devices_list:
        for mode in modes:
            rec = child(n, mode)
            points.append(rec)
            tag = "overlap" if rec["overlap"] else "sync   "
            print(f"devices={rec['devices']:2d}  batch={rec['batch']:3d}  "
                  f"{tag}  {rec['tokens_per_s']:8.0f} tok/s  "
                  f"p50={rec['latency_s']['p50']:6.2f}s "
                  f"p99={rec['latency_s']['p99']:6.2f}s", flush=True)
    # per-mode speedup curve: baseline = that mode's true 1-device point
    # when the sweep includes it; else its smallest device count (and the
    # key says so)
    for ov in sorted({p["overlap"] for p in points}):
        grp = [p for p in points if p["overlap"] == ov]
        base_pt = next((p for p in grp if p["devices"] == 1),
                       min(grp, key=lambda p: p["devices"]))
        key = ("speedup_vs_1dev" if base_pt["devices"] == 1
               else f"speedup_vs_{base_pt['devices']}dev")
        for p in grp:
            p[key] = p["tokens_per_s"] / base_pt["tokens_per_s"]
            tag = "overlap" if ov else "sync   "
            print(f"devices={p['devices']:2d}  {tag}  {key}={p[key]:5.2f}x",
                  flush=True)
    if len(modes) == 2:
        # overlap-vs-sync ratio at each device count — the honest A/B
        for n in args.devices_list:
            s = next(p for p in points
                     if p["devices"] == n and not p["overlap"])
            o = next(p for p in points if p["devices"] == n and p["overlap"])
            o["overlap_vs_sync"] = o["tokens_per_s"] / s["tokens_per_s"]
            print(f"devices={n:2d}  overlap_vs_sync="
                  f"{o['overlap_vs_sync']:5.2f}x", flush=True)
    out = {"sweep": "serve_dp_weak_scaling", "batch_per_device": args.batch,
           "budget": args.budget, "overlap": args.overlap == "on",
           "points": points}
    path = args.out or os.path.join(
        os.path.dirname(__file__), "..", "artifacts",
        "BENCH_serve_scaling.json")
    write_json(path, out)
    print(f"wrote {os.path.normpath(path)}")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--budget", type=int, default=96)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--chunks", type=int, nargs="+", default=[1, 8, 32])
    ap.add_argument("--out", default=None)
    ap.add_argument("--scaling", action="store_true",
                    help="run the data-parallel serve() scaling sweep over "
                         "--devices-list simulated host devices")
    ap.add_argument("--devices-list", type=int, nargs="+", default=[1, 2, 4, 8])
    ap.add_argument("--overlap", choices=["off", "on"], default="off",
                    help="--scaling: serve through the double-buffered "
                         "pipeline (serve(overlap=True)) instead of the "
                         "synchronous chunk-boundary loop")
    ap.add_argument("--cache", choices=["ring", "paged", "both"], default=None,
                    help="run the ring-vs-paged KV cache serve() A/B on the "
                         "mixed-exit workload ('both' writes "
                         "artifacts/BENCH_paged_cache.json)")
    ap.add_argument("--requests", type=int, default=0,
                    help="--cache workload queue length (0 = 4 * --batch)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="--cache paged backend page size (logical slots)")
    ap.add_argument("--attn", action="store_true",
                    help="run the dense-gather vs page-native decode-"
                         "attention A/B across a logical-capacity sweep "
                         "(writes artifacts/BENCH_paged_attn.json)")
    ap.add_argument("--attn-capacities", type=int, nargs="+",
                    default=[256, 512, 1024, 2048, 4096],
                    help="--attn: logical capacities to sweep")
    ap.add_argument("--attn-occupancy", type=int, default=64,
                    help="--attn: live mapped slots per row (held fixed "
                         "across the sweep)")
    ap.add_argument("--attn-iters", type=int, default=16,
                    help="--attn: decode steps per timing sample")
    ap.add_argument("--monitor", choices=["proxy"], default=None,
                    help="run the self-EAT vs black-box proxy-EAT serve() "
                         "A/B (writes artifacts/BENCH_proxy_serve.json)")
    ap.add_argument("--proxy-arch", default="tiny-proxy",
                    help="--monitor proxy: small-proxy architecture for the "
                         "probe-FLOPs ratio")
    ap.add_argument("--serve-child", type=int, default=0,
                    help=argparse.SUPPRESS)   # internal: one sweep point
    args = ap.parse_args()

    if args.reps < 1:
        # every path medians over the timed reps: zero reps would write
        # NaN seconds/tok/s into the artifact without erroring
        ap.error("--reps must be >= 1 (rep 0 is compile warmup)")
    modes = [m for m, on in (("--monitor proxy", args.monitor),
                             ("--cache", args.cache),
                             ("--scaling", args.scaling),
                             ("--attn", args.attn)) if on]
    if len(modes) > 1:
        # each mode is its own A/B with its own artifact — running one
        # silently while another flag is set hides the un-run benchmark
        ap.error(f"{' and '.join(modes)} are standalone A/Bs; run them "
                 f"separately")
    if args.overlap == "on" and not (args.scaling or args.serve_child):
        ap.error("--overlap applies to the --scaling serve sweep")

    if args.serve_child:
        rec = run_serve_child(args.serve_child, args.batch, args.budget,
                              args.reps, overlap=args.overlap == "on")
        print("SCALING_RESULT " + json.dumps(rec))
        return rec
    if args.scaling:
        return run_scaling_sweep(args)
    if args.cache:
        return run_cache_bench(args)
    if args.attn:
        return run_attn_bench(args)
    if args.monitor == "proxy":
        return run_proxy_bench(args)

    engine = build_engine(args.budget)
    batch = ChainTask().serve_batch(np.random.default_rng(0), args.batch)

    t_host, tok = measure(
        lambda st: engine._reason_per_token(st, max_tokens=args.budget),
        engine, batch, args.budget, args.reps,
    )
    base_tps = tok / t_host
    print(f"{'per-token host loop':>22s}: {t_host * 1e3:8.1f} ms  "
          f"{base_tps:8.0f} tok/s")

    rec = {"batch": args.batch, "budget": args.budget,
           "per_token": {"seconds": t_host, "tokens_per_s": base_tps},
           "chunked": {}}
    for chunk in args.chunks:
        t, tok = measure(
            lambda st: engine.reason(st, max_tokens=args.budget,
                                     chunk_len=chunk),
            engine, batch, args.budget, args.reps,
        )
        tps = tok / t
        rec["chunked"][chunk] = {"seconds": t, "tokens_per_s": tps,
                                 "speedup": tps / base_tps}
        print(f"{'chunked (len=%d)' % chunk:>22s}: {t * 1e3:8.1f} ms  "
              f"{tps:8.0f} tok/s   {tps / base_tps:5.2f}x")

    if args.out:
        write_json(args.out, rec)
    return rec


if __name__ == "__main__":
    main()
