"""Engine decode throughput: per-token host loop vs device-resident chunks.

The per-token path dispatches one jitted step per token and syncs the host
twice per iteration (``active.any()``, ``n_reasoning.max()``); the chunked
path runs a ``lax.while_loop`` of up to ``chunk_len`` monitored steps per
dispatch and syncs once per chunk.  Same tiny model, same sampler, same
EAT monitor — the measured delta is pure dispatch + sync overhead, i.e.
exactly what the probe-kernel work cannot recover from a host-bound loop.

Run:  PYTHONPATH=src python benchmarks/engine_throughput.py
      [--batch 8] [--budget 96] [--chunks 1 8 32] [--out artifacts/...json]
"""
import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.core.eat import make_probe
from repro.core.monitor import ReasoningMonitor
from repro.core.stopping import EATStopper
from repro.data.synthetic import ChainTask, Tokens
from repro.models import Model
from repro.serving.engine import EngineConfig, ReasoningEngine
from repro.serving.sampler import SamplerConfig


def build_engine(budget: int) -> ReasoningEngine:
    cfg = get_config("tiny")
    model = Model(cfg, attn_impl="xla")
    params = model.init(jax.random.PRNGKey(0))
    ecfg = EngineConfig(
        max_reasoning_tokens=budget, capacity=max(256, budget + 64),
        pad_id=Tokens.PAD, end_think_id=Tokens.END_THINK,
        newline_id=Tokens.NEWLINE, eos_id=Tokens.EOS,
        sampler=SamplerConfig(temperature=1.0, top_p=0.95),
    )
    # delta=0 -> the monitor runs (probe + EMA at every paragraph break)
    # but never fires, so both paths decode the full budget: equal work.
    monitor = ReasoningMonitor(
        stopper=EATStopper(alpha=0.2, delta=0.0),
        probe=make_probe(Tokens.END_THINK, (Tokens.ANS,)),
        newline_id=Tokens.NEWLINE,
    )
    return ReasoningEngine(model, params, ecfg, monitor)


def measure(run, engine, batch, budget: int, reps: int) -> tuple[float, int]:
    """Median wall seconds + tokens generated for ``run(state)``."""
    times, tokens = [], 0
    for rep in range(reps + 1):        # rep 0 = compile warmup
        st = engine.start(jnp.asarray(batch["prompts"]),
                          jnp.asarray(batch["prompt_len"]),
                          jax.random.PRNGKey(100 + rep))
        jax.block_until_ready(st.cache["pos"])
        t0 = time.perf_counter()
        st = run(st)
        jax.block_until_ready(st.out_tokens)
        if rep:
            times.append(time.perf_counter() - t0)
            tokens = int(np.asarray(st.n_reasoning).sum())
    return float(np.median(times)), tokens


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--budget", type=int, default=96)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--chunks", type=int, nargs="+", default=[1, 8, 32])
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    engine = build_engine(args.budget)
    batch = ChainTask().serve_batch(np.random.default_rng(0), args.batch)

    t_host, tok = measure(
        lambda st: engine._reason_per_token(st, max_tokens=args.budget),
        engine, batch, args.budget, args.reps,
    )
    base_tps = tok / t_host
    print(f"{'per-token host loop':>22s}: {t_host * 1e3:8.1f} ms  "
          f"{base_tps:8.0f} tok/s")

    rec = {"batch": args.batch, "budget": args.budget,
           "per_token": {"seconds": t_host, "tokens_per_s": base_tps},
           "chunked": {}}
    for chunk in args.chunks:
        t, tok = measure(
            lambda st: engine.reason(st, max_tokens=args.budget,
                                     chunk_len=chunk),
            engine, batch, args.budget, args.reps,
        )
        tps = tok / t
        rec["chunked"][chunk] = {"seconds": t, "tokens_per_s": tps,
                                 "speedup": tps / base_tps}
        print(f"{'chunked (len=%d)' % chunk:>22s}: {t * 1e3:8.1f} ms  "
              f"{tps:8.0f} tok/s   {tps / base_tps:5.2f}x")

    if args.out:
        os.makedirs(os.path.dirname(args.out), exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(rec, f, indent=2)
    return rec


if __name__ == "__main__":
    main()
