"""Engine decode throughput: per-token host loop vs device-resident chunks,
plus the data-parallel serve() scaling sweep.

The per-token path dispatches one jitted step per token and syncs the host
twice per iteration (``active.any()``, ``n_reasoning.max()``); the chunked
path runs a ``lax.while_loop`` of up to ``chunk_len`` monitored steps per
dispatch and syncs once per chunk.  Same tiny model, same sampler, same
EAT monitor — the measured delta is pure dispatch + sync overhead, i.e.
exactly what the probe-kernel work cannot recover from a host-bound loop.

``--scaling`` runs the continuous-batching ``serve()`` loop on (N x 1)
data-parallel meshes of 1/2/4/8 simulated host devices (one subprocess per
device count — the device count is fixed at process start) and emits
``BENCH_serve_scaling.json`` so the perf trajectory accumulates per PR.  On
one physical CPU the simulated sweep measures sharding/dispatch overhead,
not real speedup; on real chips the same harness measures both.

Run:  PYTHONPATH=src python benchmarks/engine_throughput.py
      [--batch 8] [--budget 96] [--chunks 1 8 32] [--out artifacts/...json]
      [--scaling] [--devices-list 1 2 4 8]
"""
import argparse
import json
import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.core.eat import make_probe
from repro.core.monitor import ReasoningMonitor
from repro.core.stopping import EATStopper
from repro.data.synthetic import ChainTask, Tokens
from repro.models import Model
from repro.serving.engine import EngineConfig, ReasoningEngine
from repro.serving.sampler import SamplerConfig


def build_engine(budget: int, ctx=None, capacity=None) -> ReasoningEngine:
    cfg = get_config("tiny")
    model = Model(cfg, attn_impl="xla") if ctx is None else \
        Model(cfg, ctx, attn_impl="xla")
    params = model.init(jax.random.PRNGKey(0))
    ecfg = EngineConfig(
        max_reasoning_tokens=budget,
        capacity=capacity if capacity is not None else max(256, budget + 64),
        pad_id=Tokens.PAD, end_think_id=Tokens.END_THINK,
        newline_id=Tokens.NEWLINE, eos_id=Tokens.EOS,
        sampler=SamplerConfig(temperature=1.0, top_p=0.95),
    )
    # delta=0 -> the monitor runs (probe + EMA at every paragraph break)
    # but never fires, so both paths decode the full budget: equal work.
    monitor = ReasoningMonitor(
        stopper=EATStopper(alpha=0.2, delta=0.0),
        probe=make_probe(Tokens.END_THINK, (Tokens.ANS,)),
        newline_id=Tokens.NEWLINE,
    )
    return ReasoningEngine(model, params, ecfg, monitor)


def measure(run, engine, batch, budget: int, reps: int) -> tuple[float, int]:
    """Median wall seconds + tokens generated for ``run(state)``."""
    times, tokens = [], 0
    for rep in range(reps + 1):        # rep 0 = compile warmup
        st = engine.start(jnp.asarray(batch["prompts"]),
                          jnp.asarray(batch["prompt_len"]),
                          jax.random.PRNGKey(100 + rep))
        jax.block_until_ready(st.cache["pos"])
        t0 = time.perf_counter()
        st = run(st)
        jax.block_until_ready(st.out_tokens)
        if rep:
            times.append(time.perf_counter() - t0)
            tokens = int(np.asarray(st.n_reasoning).sum())
    return float(np.median(times)), tokens


def run_serve_child(devices: int, batch_per_dev: int, budget: int,
                    reps: int) -> dict:
    """One point of the DP scaling sweep, inside a process whose device
    count was fixed by XLA_FLAGS: weak scaling — global batch =
    ``batch_per_dev * devices`` slots on an (N x 1) data-parallel mesh,
    2x-oversubscribed request queue through ``serve()``."""
    from repro.launch.mesh import make_device_ctx
    from repro.serving.scheduler import SlotScheduler

    assert len(jax.devices()) == devices, jax.devices()
    B = batch_per_dev * devices
    n_req = 2 * B
    batch = ChainTask().serve_batch(np.random.default_rng(0), n_req)
    capacity = SlotScheduler.required_capacity(
        batch["prompts"].shape[1], n_req, B, budget
    )
    engine = build_engine(budget, ctx=make_device_ctx(devices, 1),
                          capacity=capacity)

    times, tokens = [], 0
    for rep in range(reps + 1):        # rep 0 = compile warmup
        t0 = time.perf_counter()
        results = engine.serve(batch["prompts"], batch["prompt_len"],
                               jax.random.PRNGKey(100 + rep), batch_size=B,
                               max_tokens=budget)
        if rep:
            times.append(time.perf_counter() - t0)
            tokens = int(sum(r["n_reasoning"] for r in results))
    sec = float(np.median(times))
    return {"devices": devices, "batch": B, "requests": n_req,
            "budget": budget, "seconds": sec, "tokens": tokens,
            "tokens_per_s": tokens / sec}


def run_scaling_sweep(args) -> dict:
    """Fan the sweep out one subprocess per device count (the simulated
    device count is fixed at jax import) and collect
    ``BENCH_serve_scaling.json``."""
    points = []
    for n in args.devices_list:
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
        env.setdefault("JAX_PLATFORMS", "cpu")
        env["PYTHONPATH"] = os.pathsep.join(
            [os.path.join(os.path.dirname(__file__), "..", "src")]
            + [p for p in env.get("PYTHONPATH", "").split(os.pathsep) if p]
        )
        cmd = [sys.executable, os.path.abspath(__file__), "--serve-child",
               str(n), "--batch", str(args.batch),
               "--budget", str(args.budget), "--reps", str(args.reps)]
        r = subprocess.run(cmd, capture_output=True, text=True, env=env,
                           timeout=1200)
        if r.returncode != 0:
            raise RuntimeError(f"scaling child devices={n} failed:\n"
                               f"{r.stdout}\n{r.stderr}")
        line = [ln for ln in r.stdout.splitlines()
                if ln.startswith("SCALING_RESULT ")][-1]
        rec = json.loads(line[len("SCALING_RESULT "):])
        points.append(rec)
        print(f"devices={rec['devices']:2d}  batch={rec['batch']:3d}  "
              f"{rec['tokens_per_s']:8.0f} tok/s", flush=True)
    # baseline = the true 1-device point when the sweep includes it; else
    # the smallest device count (and the key says so)
    base_pt = next((p for p in points if p["devices"] == 1),
                   min(points, key=lambda p: p["devices"]))
    key = ("speedup_vs_1dev" if base_pt["devices"] == 1
           else f"speedup_vs_{base_pt['devices']}dev")
    for p in points:
        p[key] = p["tokens_per_s"] / base_pt["tokens_per_s"]
        print(f"devices={p['devices']:2d}  {key}={p[key]:5.2f}x", flush=True)
    out = {"sweep": "serve_dp_weak_scaling", "batch_per_device": args.batch,
           "budget": args.budget, "points": points}
    path = args.out or os.path.join(
        os.path.dirname(__file__), "..", "artifacts",
        "BENCH_serve_scaling.json")
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {os.path.normpath(path)}")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--budget", type=int, default=96)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--chunks", type=int, nargs="+", default=[1, 8, 32])
    ap.add_argument("--out", default=None)
    ap.add_argument("--scaling", action="store_true",
                    help="run the data-parallel serve() scaling sweep over "
                         "--devices-list simulated host devices")
    ap.add_argument("--devices-list", type=int, nargs="+", default=[1, 2, 4, 8])
    ap.add_argument("--serve-child", type=int, default=0,
                    help=argparse.SUPPRESS)   # internal: one sweep point
    args = ap.parse_args()

    if args.reps < 1:
        # every path medians over the timed reps: zero reps would write
        # NaN seconds/tok/s into the artifact without erroring
        ap.error("--reps must be >= 1 (rep 0 is compile warmup)")

    if args.serve_child:
        rec = run_serve_child(args.serve_child, args.batch, args.budget,
                              args.reps)
        print("SCALING_RESULT " + json.dumps(rec))
        return rec
    if args.scaling:
        return run_scaling_sweep(args)

    engine = build_engine(args.budget)
    batch = ChainTask().serve_batch(np.random.default_rng(0), args.batch)

    t_host, tok = measure(
        lambda st: engine._reason_per_token(st, max_tokens=args.budget),
        engine, batch, args.budget, args.reps,
    )
    base_tps = tok / t_host
    print(f"{'per-token host loop':>22s}: {t_host * 1e3:8.1f} ms  "
          f"{base_tps:8.0f} tok/s")

    rec = {"batch": args.batch, "budget": args.budget,
           "per_token": {"seconds": t_host, "tokens_per_s": base_tps},
           "chunked": {}}
    for chunk in args.chunks:
        t, tok = measure(
            lambda st: engine.reason(st, max_tokens=args.budget,
                                     chunk_len=chunk),
            engine, batch, args.budget, args.reps,
        )
        tps = tok / t
        rec["chunked"][chunk] = {"seconds": t, "tokens_per_s": tps,
                                 "speedup": tps / base_tps}
        print(f"{'chunked (len=%d)' % chunk:>22s}: {t * 1e3:8.1f} ms  "
              f"{tps:8.0f} tok/s   {tps / base_tps:5.2f}x")

    if args.out:
        os.makedirs(os.path.dirname(args.out), exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(rec, f, indent=2)
    return rec


if __name__ == "__main__":
    main()
