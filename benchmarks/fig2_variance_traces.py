"""Paper Fig. 2: EAT, its de-biased EMA variance, and the exit point chosen
by thresholding — per-question trace export (CSV artifact) + error analysis
on unsolvable questions (App. I.4: EAT must NOT stabilize early on
questions the model never solves, so Alg. 1 spends the full budget)."""
import os

import numpy as np

from benchmarks.trace_harness import ART, build_trace, pass1_at_line, replay_ema_stop


def run(out_rows: list) -> dict:
    tr = build_trace()
    L, K, B = tr["answers"].shape
    true = tr["answers_true"]
    p1 = np.stack([(tr["answers"][i] == true[None, :]).mean(0) for i in range(L)])

    line = replay_ema_stop(tr, tr["eat"], alpha=0.2, delta=2e-2)
    solved = p1[-1] >= 0.8
    unsolved = p1.max(axis=0) < 0.5

    # exit position relative to the trace end
    exit_frac = line / max(L - 1, 1)
    rec = {
        "n_solved": int(solved.sum()),
        "n_unsolved": int(unsolved.sum()),
        "mean_exit_frac_solved": float(exit_frac[solved].mean()) if solved.any() else -1,
        "mean_exit_frac_unsolved": float(exit_frac[unsolved].mean()) if unsolved.any() else -1,
    }
    # App. I.4: unsolved questions should exit later (or never) vs solved
    out_rows.append(("fig2_exit_frac_solved", 0.0, rec["mean_exit_frac_solved"]))
    out_rows.append(("fig2_exit_frac_unsolved", 0.0, rec["mean_exit_frac_unsolved"]))

    # CSV artifact with full traces for the first 6 questions
    path = os.path.join(ART, "fig2_traces.csv")
    with open(path, "w") as f:
        f.write("question,line,tokens,eat,pass1\n")
        for b in range(min(6, B)):
            for i in range(L):
                f.write(f"{b},{i},{tr['n_tokens'][i, b]},{tr['eat'][i, b]:.4f},"
                        f"{p1[i, b]:.3f}\n")
    rec["trace_csv"] = path
    return rec
