"""Paper Fig. 1: Pass@1(Avg@K), #UA@K and EAT trajectories along the chain.

Validates the paper's §3.3 claims on the synthetic reasoner:
  (i)  Pass@1 saturates at a per-question point (overthinking exists),
  (ii) EAT decreases and stabilizes at that point,
  (iii) EAT at saturation correlates with final Pass@1.
Outputs a per-question CSV + the §Paper-claims assertions.
"""
import numpy as np

from benchmarks.trace_harness import build_trace, pass1_at_line


def run(out_rows: list) -> dict:
    tr = build_trace()
    L, K, B = tr["answers"].shape
    true = tr["answers_true"]
    p1 = np.stack([(tr["answers"][i] == true[None, :]).mean(0) for i in range(L)])  # (L,B)
    ua = np.stack([
        [len(set(tr["answers"][i][:, b])) for b in range(B)] for i in range(L)
    ])  # (L,B)
    eat = tr["eat"]

    # saturation line: first line with p1 >= 0.9 that stays >= 0.8 after
    sat = np.full(B, L - 1)
    for b in range(B):
        for i in range(L):
            if p1[i, b] >= 0.9 and p1[i:, b].mean() >= 0.8:
                sat[b] = i
                break

    solved = p1[-1] >= 0.8
    # EAT drop at saturation (solved questions): mean EAT before vs after
    drops = []
    for b in np.nonzero(solved)[0]:
        s = sat[b]
        if 0 < s < L - 1:
            drops.append(eat[:s, b].mean() - eat[s:, b].mean())
    eat_drop = float(np.mean(drops)) if drops else 0.0

    overthink_frac = float(
        np.mean([(L - 1 - sat[b]) / max(L - 1, 1) for b in np.nonzero(solved)[0]])
    ) if solved.any() else 0.0

    rec = {
        "n_questions": B,
        "solved": int(solved.sum()),
        "mean_saturation_line": float(sat[solved].mean()) if solved.any() else -1,
        "mean_trace_lines": L,
        "overthink_fraction": overthink_frac,      # reasoning past saturation
        "eat_drop_at_saturation": eat_drop,        # nats
        "eat_final_solved": float(eat[-1, solved].mean()) if solved.any() else -1,
        "eat_final_unsolved": float(eat[-1, ~solved].mean()) if (~solved).any() else -1,
    }
    out_rows.append(("fig1_overthink_fraction", 0.0, rec["overthink_fraction"]))
    out_rows.append(("fig1_eat_drop_nats", 0.0, rec["eat_drop_at_saturation"]))
    return rec
