"""Paper Fig. 4 / App. I.5: EAT vs rollout confidence (Yang et al. 2025b,
Eq. 16) as early-exit signals, at two EMA window sizes.  Confidence needs a
5-token greedy rollout per evaluation (5x the probe cost); EAT is
rollout-free — same stopping machinery, so the comparison isolates the
signal."""
import numpy as np

from benchmarks.trace_harness import (
    build_trace,
    curve_auc,
    pass1_at_line,
    replay_ema_stop,
    tokens_at_line,
)


def sweep(tr, signal, deltas, alpha):
    pts = []
    for d in deltas:
        line = replay_ema_stop(tr, signal, alpha=alpha, delta=d)
        pts.append((tokens_at_line(tr, line).sum(), pass1_at_line(tr, line).mean()))
    return np.array(pts)


def run(out_rows: list) -> dict:
    tr = build_trace()
    rec = {}
    for alpha in (0.1, 0.2):
        eat_pts = sweep(tr, tr["eat"], [2.0 ** -e for e in range(0, 20)], alpha)
        # confidence stabilizes upward; its EMA-variance works identically
        conf_pts = sweep(tr, tr["confidence"], [2.0 ** -e for e in range(4, 26)], alpha)
        rec[f"auc_eat_alpha{alpha}"] = curve_auc(eat_pts[:, 0], eat_pts[:, 1])
        rec[f"auc_conf_alpha{alpha}"] = curve_auc(conf_pts[:, 0], conf_pts[:, 1])
        out_rows.append((f"fig4_auc_eat_a{alpha}", 0.0, rec[f"auc_eat_alpha{alpha}"]))
        out_rows.append((f"fig4_auc_conf_a{alpha}", 0.0, rec[f"auc_conf_alpha{alpha}"]))
    # evaluation cost ratio: confidence = rollout_len decode steps vs EAT =
    # one parallel probe forward (len 2): tokens of extra compute per eval
    rec["eval_cost_ratio_conf_over_eat"] = 5.0 / 1.0
    return rec
