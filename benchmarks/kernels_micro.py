"""Kernel micro-benchmarks: fused entropy probe vs naive materialize-logits
(CPU wall time for the XLA paths; the Pallas kernels are validated in
interpret mode by tests and targeted at TPU)."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.entropy_probe.ops import _xla_entropy
from repro.kernels.entropy_probe.ref import next_token_entropy_ref


def _time(fn, n=10):
    fn()
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6   # us


def run(out_rows: list) -> dict:
    rec = {}
    d = 1024
    for V in (32_768, 131_072):
        h = jax.random.normal(jax.random.PRNGKey(0), (8, d), jnp.float32)
        w = jax.random.normal(jax.random.PRNGKey(1), (d, V), jnp.float32) * 0.05
        f_ref = jax.jit(lambda h, w: next_token_entropy_ref(h, w, V))
        f_onl = jax.jit(lambda h, w: _xla_entropy(h, w, V, block_v=8192))
        np.testing.assert_allclose(np.asarray(f_ref(h, w)), np.asarray(f_onl(h, w)),
                                   atol=1e-4, rtol=1e-4)
        t_ref = _time(lambda: f_ref(h, w).block_until_ready())
        t_onl = _time(lambda: f_onl(h, w).block_until_ready())
        rec[f"V{V}"] = {"naive_us": t_ref, "online_us": t_onl}
        out_rows.append((f"kernel_entropy_naive_V{V}", t_ref, 0.0))
        out_rows.append((f"kernel_entropy_online_V{V}", t_onl, t_ref / max(t_onl, 1e-9)))
    return rec
