"""Shared helpers for the examples: get (train-if-missing) the synthetic
reasoning model, build engines."""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.core.eat import make_probe
from repro.core.monitor import ReasoningMonitor
from repro.core.stopping import EATStopper
from repro.data.pipeline import train_batches
from repro.data.synthetic import ChainTask, Tokens
from repro.models import Model
from repro.serving.engine import EngineConfig, ReasoningEngine
from repro.serving.sampler import SamplerConfig
from repro.training.checkpoint import load_checkpoint, save_checkpoint
from repro.training.optimizer import AdamWConfig
from repro.training.train_loop import TrainConfig, init_train_state, make_train_step

CKPT = os.path.join(os.path.dirname(__file__), "..", "artifacts", "tiny_reasoner.ckpt")


def get_reasoner(train_steps: int = 1200, verbose: bool = True):
    """Returns (model, params, task). Trains + checkpoints on first use."""
    cfg = get_config("tiny-reasoner")
    model = Model(cfg, attn_impl="xla")
    task = ChainTask()
    params_like = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    if os.path.exists(CKPT):
        params = load_checkpoint(CKPT, params_like)
        return model, params, task
    if verbose:
        print(f"training tiny-reasoner for {train_steps} steps (first run)...")
    state = init_train_state(model, jax.random.PRNGKey(0))
    tcfg = TrainConfig(
        opt=AdamWConfig(lr=1e-3, warmup_steps=50, total_steps=train_steps),
        remat=False,
    )
    step_fn = jax.jit(make_train_step(model, tcfg), donate_argnums=0)
    t0 = time.time()
    for i, batch in zip(range(train_steps), train_batches(task, 64, seed=0)):
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        state, metrics = step_fn(state, batch)
        if verbose and i % 200 == 0:
            print(f"  step {i}: loss={float(metrics['loss']):.3f} "
                  f"acc={float(metrics['accuracy']):.3f} ({time.time()-t0:.0f}s)")
    save_checkpoint(CKPT, state.params)
    return model, state.params, task


def make_engine(model, params, *, alpha=0.2, delta=1e-3, max_tokens=110,
                temperature=0.6, min_evals=2) -> ReasoningEngine:
    ecfg = EngineConfig(
        max_reasoning_tokens=max_tokens, capacity=192,
        pad_id=Tokens.PAD, end_think_id=Tokens.END_THINK,
        newline_id=Tokens.NEWLINE, eos_id=Tokens.EOS,
        sampler=SamplerConfig(temperature=temperature, top_p=0.95),
    )
    monitor = ReasoningMonitor(
        stopper=EATStopper(alpha=alpha, delta=delta),
        probe=make_probe(Tokens.END_THINK, (Tokens.ANS,)),
        newline_id=Tokens.NEWLINE,
        min_evals=min_evals,
    )
    return ReasoningEngine(model, params, ecfg, monitor)


def pass_at_1(engine, state, answers: np.ndarray, k: int, rng) -> np.ndarray:
    """Pass@1(Avg@k) per sequence (paper Eq. 9)."""
    rolls = engine.rollout_answers(state, k, n_tokens=4, rng=rng)   # (k,B,4)
    got = np.stack([ChainTask.extract_answer(np.asarray(rolls[i]))
                    for i in range(k)])                              # (k,B)
    return (got == answers[None, :]).mean(axis=0)
