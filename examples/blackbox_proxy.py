"""Black-box early exiting (paper §4.2, Fig. 5): a PROXY model monitors the
verbal reasoning stream of a different model and decides when to stop it.

theta (the "API" reasoning model) = the trained tiny-reasoner.
phi   (the local proxy)           = an independently-initialized copy trained
with a different seed/steps — different weights, same tokenizer, mirroring
the paper's Qwen-1.5B-monitors-Llama-70B setup at toy scale.

The stream arrives in chunks; the proxy prefills each chunk into its own
KV cache and evaluates EAT.  We also report the overlap headroom: proxy
probe time vs generator chunk time (Fig. 5b's comparison).

Run:  PYTHONPATH=src python examples/blackbox_proxy.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from examples.common import get_reasoner, make_engine
from repro.configs.base import get_config
from repro.core.eat import make_probe
from repro.core.monitor import ReasoningMonitor
from repro.core.stopping import EATStopper
from repro.data.synthetic import ChainTask, Tokens
from repro.models import Model
from repro.serving.proxy import ProxyMonitor

CHUNK = 8


def main():
    model, params, task = get_reasoner()
    engine = make_engine(model, params, max_tokens=80)

    # proxy: same family, different weights (quick fine-tune from scratch)
    import examples.common as C
    ckpt = C.CKPT
    C.CKPT = ckpt.replace(".ckpt", "_proxy.ckpt")
    proxy_model, proxy_params, _ = get_reasoner(train_steps=600)
    C.CKPT = ckpt

    monitor = ReasoningMonitor(
        stopper=EATStopper(alpha=0.2, delta=1e-3),
        probe=make_probe(Tokens.END_THINK, (Tokens.ANS,)),
        newline_id=Tokens.NEWLINE, min_evals=2,
    )
    proxy = ProxyMonitor(model=proxy_model, params=proxy_params,
                         monitor=monitor, capacity=192)

    rng = np.random.default_rng(11)
    batch = task.serve_batch(rng, 4)
    print("difficulties:", batch["k"])

    st = engine.start(jnp.asarray(batch["prompts"]), jnp.asarray(batch["prompt_len"]),
                      jax.random.PRNGKey(0))
    pst = proxy.start(jnp.asarray(batch["prompts"]), jnp.asarray(batch["prompt_len"]))

    gen_times, stopped_at = [], np.full(4, -1)
    for chunk_i in range(10):
        t0 = time.perf_counter()
        buf = []
        for _ in range(CHUNK):                       # theta generates a chunk
            st = engine._decode_fn(engine.params, st)
            buf.append(np.asarray(st.last_token))
        gen_times.append(time.perf_counter() - t0)
        chunk = jnp.asarray(np.stack(buf, axis=1))   # (B, CHUNK)
        pst = proxy.observe_chunk(pst, chunk, active=st.active)
        stop = np.asarray(proxy.should_stop(pst))
        newly = stop & (stopped_at < 0)
        stopped_at[newly] = (chunk_i + 1) * CHUNK
        st = st._replace(active=st.active & ~jnp.asarray(stop) & ~st.ended_think)
        print(f"chunk {chunk_i}: EAT={np.asarray(pst['last_eat']).round(2)} "
              f"stop={stop} gen={gen_times[-1]*1e3:.0f}ms "
              f"probe={pst['probe_seconds'][-1]*1e3:.0f}ms")
        if not bool(st.active.any()):
            break

    print(f"\nstopped_at (tokens): {stopped_at}")
    print(f"mean generator chunk time: {np.mean(gen_times)*1e3:.1f} ms; "
          f"mean proxy probe time: {np.mean(pst['probe_seconds'])*1e3:.1f} ms")
    print("probe < chunk time -> monitoring hides behind generation "
          "(paper Fig. 5b).")


if __name__ == "__main__":
    main()
