"""Quickstart: watch EAT fall as a reasoning model thinks (paper Fig. 1).

Generates one reasoning chain per question with the trained synthetic
reasoner and prints, at every paragraph break, the EAT value, its EMA
variance, and Pass@1(Avg@16) — the paper's core phenomenon:

  * Pass@1 saturates once the model has done k computation steps,
  * EAT collapses from ~ln(10) to ~0 at exactly that point,
  * extra "verification" lines after that are pure overthinking.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from examples.common import get_reasoner, make_engine, pass_at_1
from repro.data.synthetic import ChainTask


def main():
    model, params, task = get_reasoner()
    engine = make_engine(model, params, delta=1e-3)

    rng = np.random.default_rng(7)
    batch = task.serve_batch(rng, 4)
    print("difficulties k:", batch["k"], " answers:", batch["answers"])

    st = engine.start(jnp.asarray(batch["prompts"]), jnp.asarray(batch["prompt_len"]),
                      jax.random.PRNGKey(0))
    st, trace = engine.reason_with_trace(
        st, max_tokens=110, rollout_k=16, rollout_len=4,
        answer_extract=ChainTask.extract_answer,
    )

    print(f"\n{'line':>4} {'tokens':>7} | " +
          " | ".join(f"q{i}(k={int(batch['k'][i])}) EAT  var   P@1"
                     for i in range(4)))
    for li, rec in enumerate(trace):
        p1 = (rec["answers"] == batch["answers"][None, :]).mean(0)
        cells = [
            f"{rec['eat'][i]:4.2f} {rec['ema_var'][i]:6.0e} {p1[i]:4.2f}"
            for i in range(4)
        ]
        print(f"{li:>4} {int(rec['n_tokens'].max()):>7} | " + " | ".join(cells))

    toks, _ = engine.force_answer(st, 4)
    final = ChainTask.extract_answer(np.asarray(toks))
    print("\nfinal answers:", final, " correct:", (final == batch["answers"]))


if __name__ == "__main__":
    main()
