"""End-to-end serving driver: batched requests, EAT early exit vs the
token-budget baseline (paper Fig. 3 protocol, live — not post-hoc).

Serves a batch of synthetic reasoning questions three ways:
  1. token-budget baseline (Alg. 2) at a fixed T,
  2. EAT early exit (Alg. 1) at a threshold delta,
  3. no early exit (natural </think> or max budget),
and reports aggregate Pass@1 and total reasoning-token usage for each.

Run:  PYTHONPATH=src python examples/serve_eat.py [--batch 16] [--delta 1e-3]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from examples.common import get_reasoner, make_engine, pass_at_1


def serve(engine, batch, *, use_monitor, max_tokens, seed=0):
    st = engine.start(jnp.asarray(batch["prompts"]), jnp.asarray(batch["prompt_len"]),
                      jax.random.PRNGKey(seed))
    st = engine.reason(st, max_tokens=max_tokens, use_monitor=use_monitor)
    p1 = pass_at_1(engine, st, batch["answers"], k=16, rng=jax.random.PRNGKey(seed + 1))
    tokens = int(np.asarray(st.n_reasoning).sum())
    return p1.mean(), tokens, np.asarray(st.n_reasoning)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--delta", type=float, default=1e-3)
    ap.add_argument("--budget", type=int, default=110)
    args = ap.parse_args()

    model, params, task = get_reasoner()
    rng = np.random.default_rng(3)
    batch = task.serve_batch(rng, args.batch)
    print(f"serving {args.batch} questions, difficulty k in "
          f"[{batch['k'].min()}, {batch['k'].max()}]\n")

    eng_plain = make_engine(model, params, max_tokens=args.budget)
    p1, tok, per = serve(eng_plain, batch, use_monitor=False, max_tokens=args.budget)
    print(f"{'no early exit':>24s}: Pass@1={p1:.3f}  tokens={tok:5d}")

    for T in (args.budget, args.budget // 2, args.budget // 4):
        p1, tokens, _ = serve(eng_plain, batch, use_monitor=False, max_tokens=T)
        print(f"{'token budget T=' + str(T):>24s}: Pass@1={p1:.3f}  tokens={tokens:5d}")

    for delta in (args.delta * 10, args.delta, args.delta / 10):
        eng = make_engine(model, params, delta=delta, max_tokens=args.budget)
        p1, tokens, per = serve(eng, batch, use_monitor=True, max_tokens=args.budget)
        print(f"{'EAT delta=%.0e' % delta:>24s}: Pass@1={p1:.3f}  tokens={tokens:5d}  "
              f"(per-q: min {per.min()}, max {per.max()})")

    print("\nEAT allocates tokens per difficulty; the fixed budget cannot.")


if __name__ == "__main__":
    main()
