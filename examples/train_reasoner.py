"""Training driver: train the synthetic reasoning model from scratch.

This exercises the full training substrate (data pipeline -> pjit train
step -> AdamW -> checkpointing).  The serving examples load the resulting
checkpoint.  On the production mesh the same code path trains the assigned
architectures (see repro/launch/train.py); here it runs the tiny config on
CPU in a few minutes.

Run:  PYTHONPATH=src python examples/train_reasoner.py [--steps 1200]
"""
import argparse

from examples.common import CKPT, get_reasoner


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=1200)
    args = ap.parse_args()
    import os

    if os.path.exists(CKPT):
        print(f"checkpoint already at {CKPT}; delete it to retrain")
        return
    get_reasoner(train_steps=args.steps)
    print(f"saved {CKPT}")


if __name__ == "__main__":
    main()
