"""Mesh equivalence: ``serve()``/``reason()`` on a 4x2 (data x model) mesh
of 8 simulated host devices must produce token-for-token identical outputs,
exit steps, and EAT trajectories to single-device serving on the tiny
config — through BOTH cache backends: the dense ring and the block-paged
pool (the paged mesh run is compared against the single-device RING run, so
one assertion pins backend x mesh equivalence at once), and through BOTH
monitor tiers: self-EAT and the black-box proxy (``monitor="proxy"`` with a
same-params proxy is bit-equal to single-device self-EAT —
tests/test_proxy_serve.py — so the single self reference pins mesh
proxy-driven exits too).  Real multi-shard semantics need >1 device, so the
meat runs in a subprocess with 8 forced host devices (tests keep 1 device,
like ``test_sharded_attention``)."""
import os
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import get_config
from repro.core.eat import make_probe
from repro.core.monitor import ReasoningMonitor
from repro.core.stopping import EATStopper
from repro.data.synthetic import ChainTask, Tokens
from repro.launch.mesh import local_ctx, make_device_ctx
from repro.models import Model
from repro.serving.cache import CacheConfig
from repro.serving.engine import EngineConfig, ReasoningEngine
from repro.serving.proxy import ProxyConfig
from repro.serving.sampler import SamplerConfig

assert len(jax.devices()) == 8, jax.devices()

def build(ctx, delta, cache_kind="ring", proxy=False, attn="gather"):
    cfg = get_config("tiny")
    model = Model(cfg, ctx, attn_impl="xla")
    params = model.init(jax.random.PRNGKey(11))   # same key => same weights
    ecfg = EngineConfig(
        max_reasoning_tokens=24, capacity=256,
        pad_id=Tokens.PAD, end_think_id=Tokens.END_THINK,
        newline_id=Tokens.NEWLINE, eos_id=Tokens.EOS, chunk_len=8,
        sampler=SamplerConfig(greedy=True),
        cache=CacheConfig(kind=cache_kind, page_size=16, attn_impl=attn),
    )
    monitor = ReasoningMonitor(
        stopper=EATStopper(alpha=0.2, delta=delta),
        probe=make_probe(Tokens.END_THINK, (Tokens.ANS,)),
        schedule="every_n", every_n=4, min_evals=1,
    )
    pcfg = ProxyConfig(model=model, params=params) if proxy else None
    return ReasoningEngine(model, params, ecfg, monitor, proxy=pcfg)

task = ChainTask()
b = task.serve_batch(np.random.default_rng(7), 6)

# ---- serve(): continuous batching, early exit at the first EAT eval; the
# single-device ring run is the one reference every (mesh, cache) variant
# must reproduce token-for-token
for delta in (1e9, 0.0):      # exit-at-first-eval AND run-to-budget regimes
    ref_eng = build(local_ctx(), delta)
    ref = ref_eng.serve(b["prompts"], b["prompt_len"], jax.random.PRNGKey(0),
                        batch_size=4, max_tokens=24, answer_len=4,
                        record_trace=True)
    for kind in ("ring", "paged"):
        mesh_eng = build(make_device_ctx(4, 2), delta, cache_kind=kind)
        out = mesh_eng.serve(b["prompts"], b["prompt_len"],
                             jax.random.PRNGKey(0),
                             batch_size=4, max_tokens=24, answer_len=4,
                             record_trace=True)
        for r, o in zip(ref, out):
            assert r["n_reasoning"] == o["n_reasoning"], (delta, kind, r, o)
            assert r["exit_reason"] == o["exit_reason"], (delta, kind, r, o)
            assert r["ended_think"] == o["ended_think"], (delta, kind, r, o)
            np.testing.assert_array_equal(r["reasoning_tokens"],
                                          o["reasoning_tokens"])
            np.testing.assert_array_equal(r["answer_tokens"],
                                          o["answer_tokens"])
            # EAT trajectory: same schedule, same EMA variance values
            assert len(r["eat_trace"]) == len(o["eat_trace"]), (delta, kind)
            for (n1, e1, v1), (n2, e2, v2) in zip(r["eat_trace"],
                                                  o["eat_trace"]):
                assert (n1, e1) == (n2, e2)
                np.testing.assert_allclose(v1, v2, atol=1e-5)
        print(f"serve delta={delta} cache={kind} equivalent "
              f"over {len(ref)} requests")

# ---- page-native attention on the mesh (tiny's 2 kv heads divide the
# model axis, so the pools shard over heads and the page list replicates):
# mesh paged(native) must reproduce the single-device ring(native) run —
# the per-impl paged==ring pairing holds under GSPMD too
ref = build(local_ctx(), 0.0, attn="xla").serve(
    b["prompts"], b["prompt_len"], jax.random.PRNGKey(0), batch_size=4,
    max_tokens=24, answer_len=4, record_trace=True)
out = build(make_device_ctx(4, 2), 0.0, cache_kind="paged",
            attn="xla").serve(
    b["prompts"], b["prompt_len"], jax.random.PRNGKey(0), batch_size=4,
    max_tokens=24, answer_len=4, record_trace=True)
for r, o in zip(ref, out):
    assert r["n_reasoning"] == o["n_reasoning"], ("native", r, o)
    assert r["exit_reason"] == o["exit_reason"], ("native", r, o)
    assert r["ended_think"] == o["ended_think"], ("native", r, o)
    np.testing.assert_array_equal(r["reasoning_tokens"],
                                  o["reasoning_tokens"])
    np.testing.assert_array_equal(r["answer_tokens"], o["answer_tokens"])
    assert len(r["eat_trace"]) == len(o["eat_trace"]), "native"
    for (n1, e1, v1), (n2, e2, v2) in zip(r["eat_trace"], o["eat_trace"]):
        assert (n1, e1) == (n2, e2)
        np.testing.assert_allclose(v1, v2, atol=1e-5)
print(f"serve attn=page-native paged-mesh == ring-1dev over {len(ref)} "
      f"requests")

# ---- monitor="proxy" on the mesh: the generator decodes blind and a
# same-params proxy supplies the exits — outputs must still match the
# single-device SELF reference token-for-token through both backends (the
# proxy-driven-exit regime, delta=1e9: every request exits at the proxy's
# first evaluation)
ref_eng = build(local_ctx(), 1e9)
ref = ref_eng.serve(b["prompts"], b["prompt_len"], jax.random.PRNGKey(0),
                    batch_size=4, max_tokens=24, answer_len=4,
                    record_trace=True)
for kind in ("ring", "paged"):
    mesh_eng = build(make_device_ctx(4, 2), 1e9, cache_kind=kind, proxy=True)
    out = mesh_eng.serve(b["prompts"], b["prompt_len"], jax.random.PRNGKey(0),
                         batch_size=4, max_tokens=24, answer_len=4,
                         record_trace=True)
    for r, o in zip(ref, out):
        assert r["n_reasoning"] == o["n_reasoning"], (kind, r, o)
        assert r["exit_reason"] == o["exit_reason"], (kind, r, o)
        assert r["ended_think"] == o["ended_think"], (kind, r, o)
        np.testing.assert_array_equal(r["reasoning_tokens"],
                                      o["reasoning_tokens"])
        np.testing.assert_array_equal(r["answer_tokens"], o["answer_tokens"])
        assert len(r["eat_trace"]) == len(o["eat_trace"]), kind
        for (n1, e1, v1), (n2, e2, v2) in zip(r["eat_trace"], o["eat_trace"]):
            assert (n1, e1) == (n2, e2)
            np.testing.assert_allclose(v1, v2, atol=1e-5)
    # black-box contract holds on the mesh too
    gk = mesh_eng.executor._programs
    assert not [k for k in gk if k[0] == "probe"], gk.keys()
    assert not [k for k in gk if k[0] == "chunk" and k[2]], gk.keys()
    print(f"serve monitor=proxy cache={kind} equivalent over {len(ref)} "
          f"requests")

# ---- reason(): one batch, monitored, compare exit latches + EAT values
ref_eng = build(local_ctx(), 1e9)
mesh_eng = build(make_device_ctx(4, 2), 1e9)
st_r = ref_eng.start(jnp.asarray(b["prompts"][:4]),
                     jnp.asarray(b["prompt_len"][:4]), jax.random.PRNGKey(2))
st_m = mesh_eng.start(jnp.asarray(b["prompts"][:4]),
                      jnp.asarray(b["prompt_len"][:4]), jax.random.PRNGKey(2))
np.testing.assert_allclose(np.asarray(ref_eng.eval_eat_now(st_r)),
                           np.asarray(mesh_eng.eval_eat_now(st_m)), atol=1e-5)
st_r = ref_eng.reason(st_r)
st_m = mesh_eng.reason(st_m)
np.testing.assert_array_equal(np.asarray(st_r.out_tokens),
                              np.asarray(st_m.out_tokens))
np.testing.assert_array_equal(np.asarray(st_r.n_reasoning),
                              np.asarray(st_m.n_reasoning))
np.testing.assert_array_equal(np.asarray(st_r.monitor.stop_flag),
                              np.asarray(st_m.monitor.stop_flag))
np.testing.assert_array_equal(np.asarray(st_r.monitor.n_evals),
                              np.asarray(st_m.monitor.n_evals))
print("reason equivalent")
print("done")
"""


def test_mesh_serve_equivalence_8dev():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, env=env, timeout=900)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "done" in r.stdout
