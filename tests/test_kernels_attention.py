"""Shape/dtype sweeps: flash_attention + decode_attention Pallas kernels
(interpret mode) and the XLA chunked path vs the pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention.kernel import decode_attention_pallas
from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ops import _xla_attention, attention
from repro.kernels.flash_attention.ref import attention_ref


def make_inputs(B, Sq, Skv, Hq, Hkv, Dk, Dv, dtype, offset=0, invalid=0, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, Sq, Hq, Dk)).astype(dtype)
    k = jax.random.normal(ks[1], (B, Skv, Hkv, Dk)).astype(dtype)
    v = jax.random.normal(ks[2], (B, Skv, Hkv, Dv)).astype(dtype)
    q_pos = jnp.broadcast_to(jnp.arange(Sq) + offset, (B, Sq)).astype(jnp.int32)
    kv_pos = jnp.broadcast_to(jnp.arange(Skv), (B, Skv)).astype(jnp.int32)
    if invalid:
        kv_pos = kv_pos.at[:, -invalid:].set(-1)
    return q, k, v, q_pos, kv_pos


SWEEP = [
    # B, Sq, Skv, Hq, Hkv, Dk, Dv, window
    (1, 16, 16, 1, 1, 32, 32, 0),
    (2, 33, 47, 4, 2, 64, 64, 0),
    (2, 33, 47, 4, 2, 64, 64, 8),
    (1, 8, 128, 8, 1, 128, 128, 0),      # MQA
    (2, 17, 40, 6, 3, 80, 80, 16),       # zamba-ish head_dim 80
    (1, 12, 30, 4, 1, 96, 64, 0),        # Dv != Dk (MLA absorbed-ish)
]


@pytest.mark.parametrize("case", SWEEP)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_kernel_matches_ref(case, dtype):
    B, Sq, Skv, Hq, Hkv, Dk, Dv, window = case
    q, k, v, qp, kp = make_inputs(B, Sq, Skv, Hq, Hkv, Dk, Dv, dtype, offset=4, invalid=3)
    ref = attention_ref(q, k, v, qp, kp, window=window)
    out = flash_attention_pallas(q, k, v, qp, kp, window=window,
                                 block_q=16, block_kv=16, interpret=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("case", SWEEP)
def test_xla_attention_matches_ref(case):
    B, Sq, Skv, Hq, Hkv, Dk, Dv, window = case
    q, k, v, qp, kp = make_inputs(B, Sq, Skv, Hq, Hkv, Dk, Dv, jnp.float32, invalid=2)
    ref = attention_ref(q, k, v, qp, kp, window=window)
    out = _xla_attention(q, k, v, qp, kp, causal=True, window=window,
                         scale=1.0 / Dk ** 0.5, q_chunk=16, kv_chunk=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("m", [1, 2, 5])
@pytest.mark.parametrize("window", [0, 16])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_kernel_matches_ref(m, window, dtype):
    B, Hq, Hkv, Dk, Dv, C = 2, 8, 2, 64, 32, 70
    q, k, v, qp, kp = make_inputs(B, m, C, Hq, Hkv, Dk, Dv, dtype, offset=40, invalid=20)
    ref = attention_ref(q, k, v, qp, kp, window=window)
    out = decode_attention_pallas(q, k, v, qp, kp, window=window,
                                  block_kv=32, interpret=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


def test_noncausal_cross_attention():
    q, k, v, qp, kp = make_inputs(2, 9, 21, 4, 4, 32, 32, jnp.float32)
    ref = attention_ref(q, k, v, qp, kp, causal=False)
    out = attention(q, k, v, qp, kp, causal=False, impl="xla")
    pal = flash_attention_pallas(q, k, v, qp, kp, causal=False,
                                 block_q=8, block_kv=8, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(pal), np.asarray(ref), atol=1e-5, rtol=1e-5)


def test_ring_buffer_slot_order_irrelevant():
    """Attention must depend on positions, not slot order (ring caches)."""
    B, m, C = 1, 1, 16
    q, k, v, qp, kp = make_inputs(B, m, C, 2, 1, 32, 32, jnp.float32, offset=C)
    perm = jax.random.permutation(jax.random.PRNGKey(9), C)
    ref = attention_ref(q, k, v, qp, kp)
    out = attention_ref(q, k[:, perm], v[:, perm], qp, kp[:, perm])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5, rtol=1e-5)
