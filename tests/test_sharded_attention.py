"""Correctness of the seq-sharded partial-softmax decode attention
(§Perf P1').  Real multi-shard semantics need >1 device, so the meat runs
in a subprocess with 8 forced host devices (the 512-device flag stays
confined to dry-run processes; tests keep 1 device)."""
import os
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs.base import get_config
from repro.models.attention import seq_sharded_decode_attention, use_seq_sharded_cache
from repro.kernels.flash_attention.ref import attention_ref
from repro.sharding.partition import ShardCtx

mesh = jax.make_mesh((2, 4), ("data", "model"))
ctx = ShardCtx(mesh=mesh, batch_axes=("data",))

B, m, Hq, Hkv, Dk, Dv, C = 4, 3, 8, 2, 16, 16, 32
ks = jax.random.split(jax.random.PRNGKey(0), 3)
q = jax.random.normal(ks[0], (B, m, Hq, Dk))
k = jax.random.normal(ks[1], (B, C, Hkv, Dk))
v = jax.random.normal(ks[2], (B, C, Hkv, Dv))
q_pos = jnp.broadcast_to(jnp.arange(m) + 20, (B, m)).astype(jnp.int32)
kv_pos = jnp.broadcast_to(jnp.arange(C), (B, C)).astype(jnp.int32)
kv_pos = kv_pos.at[:, 23:].set(-1)

for window in (0, 8):
    ref = attention_ref(q, k, v, q_pos, kv_pos, window=window, scale=0.25)
    fn = jax.jit(lambda q, k, v, qp, kp: seq_sharded_decode_attention(
        q, k, v, qp, kp, ctx, window=window, scale=0.25))
    out = fn(
        jax.device_put(q, NamedSharding(mesh, P("data", None, None, None))),
        jax.device_put(k, NamedSharding(mesh, P("data", "model", None, None))),
        jax.device_put(v, NamedSharding(mesh, P("data", "model", None, None))),
        jax.device_put(q_pos, NamedSharding(mesh, P("data", None))),
        jax.device_put(kv_pos, NamedSharding(mesh, P("data", "model"))),
    )
    err = float(jnp.abs(out - ref).max())
    assert err < 1e-5, (window, err)
    print(f"window={window} ok err={err:.2e}")

# predicate sanity: gemma-2b kv=1 not divisible by model=4 -> sharded path;
# zamba2 kv=32 divisible -> head-sharded path; prefill (m large) -> never
assert use_seq_sharded_cache(get_config("gemma-2b"), ctx, 1)
assert not use_seq_sharded_cache(get_config("zamba2-2.7b"), ctx, 1)
assert not use_seq_sharded_cache(get_config("gemma-2b"), ctx, 512)
assert use_seq_sharded_cache(get_config("deepseek-v2-236b"), ctx, 1)  # MLA
print("done")
"""


def test_seq_sharded_decode_attention_multidevice():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, env=env, timeout=600)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "done" in r.stdout


def test_predicate_single_device():
    from repro.configs.base import get_config
    from repro.models.attention import use_seq_sharded_cache
    from repro.sharding.partition import ShardCtx

    assert not use_seq_sharded_cache(get_config("qwen3-1.7b"), ShardCtx(mesh=None), 1)
