"""Per-assigned-architecture smoke tests (deliverable f).

For each of the 10 assigned architectures: instantiate the REDUCED variant
of the same family (<=2 layers, d_model<=128, <=4 experts — see
``ModelConfig.reduced``), run one forward/train step and one
prefill+decode+EAT-probe cycle on CPU, and assert output shapes + no NaNs.
The FULL configs are exercised only via the dry-run.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS
from repro.configs.base import get_config
from repro.models import Model
from repro.serving.cache import alloc_cache
from repro.training.train_loop import TrainConfig, init_train_state, make_train_step
from repro.training.optimizer import AdamWConfig


def _batch_for(cfg, B=2, S=12):
    rng = jax.random.PRNGKey(0)
    S_text = S - (cfg.n_image_patches if cfg.arch_type == "vlm" else 0)
    toks = jax.random.randint(rng, (B, S_text), 0, cfg.vocab)
    pos1d = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    positions = (jnp.broadcast_to(pos1d[..., None], (B, S, 3))
                 if cfg.mrope_sections else pos1d)
    batch = {
        "tokens": toks,
        "targets": jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab),
        "loss_mask": jnp.ones((B, S), jnp.float32),
        "positions": positions,
        "pos1d": pos1d,
    }
    if cfg.arch_type == "vlm":
        batch["image_embeds"] = 0.1 * jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.n_image_patches, cfg.d_model)
        )
    if cfg.arch_type == "encdec":
        batch["frames"] = 0.1 * jax.random.normal(
            jax.random.PRNGKey(3), (B, cfg.encoder_len, cfg.d_model)
        )
    return batch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_reduced_train_step(arch):
    cfg = get_config(arch).reduced()
    model = Model(cfg, attn_impl="xla")
    state = init_train_state(model, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(model, TrainConfig(opt=AdamWConfig(lr=1e-3), remat=False)))
    batch = _batch_for(cfg)
    state, metrics = step(state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss), (arch, loss)
    for _, leaf in ((p, l) for p, l in [(None, x) for x in jax.tree_util.tree_leaves(state.params)]):
        assert np.isfinite(np.asarray(leaf, np.float32)).all(), arch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_reduced_serve_cycle(arch):
    """prefill -> decode one token -> EAT probe; shapes + finiteness."""
    cfg = get_config(arch).reduced()
    model = Model(cfg, attn_impl="xla")
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 8
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    pos1d = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    positions = (jnp.broadcast_to(pos1d[..., None], (B, S, 3))
                 if cfg.mrope_sections else pos1d)
    cache = alloc_cache(cfg, B, 16)
    kw = {}
    if cfg.arch_type == "encdec":
        kw["frames"] = 0.1 * jax.random.normal(jax.random.PRNGKey(2), (B, cfg.encoder_len, cfg.d_model))
    if cfg.arch_type == "vlm":
        kw["image_embeds"] = 0.1 * jax.random.normal(
            jax.random.PRNGKey(3), (B, cfg.n_image_patches, cfg.d_model)
        )
        # image patches occupy the first slots; needs capacity
        cache = alloc_cache(cfg, B, 16 + cfg.n_image_patches)
        pos1d = pos1d + cfg.n_image_patches
        img_pos = jnp.broadcast_to(
            jnp.arange(cfg.n_image_patches, dtype=jnp.int32), (B, cfg.n_image_patches)
        )
        pos1d = jnp.concatenate([img_pos, pos1d], axis=1)
        positions = jnp.broadcast_to(pos1d[..., None], pos1d.shape + (3,))

    hidden, cache = model.prefill(params, toks, positions, pos1d, cache, **kw)
    d = cfg.d_model
    assert hidden.shape[0] == B and hidden.shape[-1] == d
    assert np.isfinite(np.asarray(hidden, np.float32)).all(), arch

    npos = pos1d[:, -1:] + 1
    np3 = jnp.broadcast_to(npos[..., None], (B, 1, 3)) if cfg.mrope_sections else npos
    logits, cache = model.decode_step(
        params, jnp.zeros((B, 1), jnp.int32), np3, npos, cache
    )
    assert logits.shape == (B, 1, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), arch

    # EAT probe: does not commit the cache
    pos_before = np.asarray(cache["pos"]).copy()
    ppos = npos + 1
    pp3 = jnp.broadcast_to(ppos[..., None], (B, 1, 3)) if cfg.mrope_sections else ppos
    eat = model.probe_entropy(params, jnp.ones((B, 1), jnp.int32), pp3, ppos, cache)
    assert eat.shape == (B,)
    assert np.isfinite(np.asarray(eat)).all() and (np.asarray(eat) >= 0).all(), arch
    np.testing.assert_array_equal(np.asarray(cache["pos"]), pos_before)


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_config_matches_assignment(arch):
    """Exact assigned hyperparameters are encoded (spot checks)."""
    cfg = get_config(arch)
    expected = {
        "deepseek-v2-236b": dict(n_layers=60, d_model=5120, n_heads=128, vocab=102400),
        "mamba2-2.7b": dict(n_layers=64, d_model=2560, vocab=50280),
        "codeqwen1.5-7b": dict(n_layers=32, d_model=4096, n_heads=32, d_ff=13440, vocab=92416),
        "seamless-m4t-large-v2": dict(n_layers=24, d_model=1024, n_heads=16, d_ff=8192, vocab=256206),
        "gemma-2b": dict(n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, d_ff=16384, vocab=256000),
        "deepseek-moe-16b": dict(n_layers=28, d_model=2048, n_heads=16, vocab=102400),
        "zamba2-2.7b": dict(n_layers=54, d_model=2560, n_heads=32, d_ff=10240, vocab=32000),
        "qwen3-1.7b": dict(n_layers=28, d_model=2048, n_heads=16, n_kv_heads=8, d_ff=6144, vocab=151936),
        "qwen2-vl-7b": dict(n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4, d_ff=18944, vocab=152064),
        "gemma-7b": dict(n_layers=28, d_model=3072, n_heads=16, d_ff=24576, vocab=256000),
    }[arch]
    for k, v in expected.items():
        assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)
    if arch == "deepseek-v2-236b":
        assert cfg.moe.n_routed == 160 and cfg.moe.top_k == 6 and cfg.moe.n_shared == 2
        assert cfg.mla.kv_lora_rank == 512
        # 236B total / ~21B active (paper's numbers)
        assert 2.2e11 < cfg.param_count() < 2.5e11
        assert 1.9e10 < cfg.param_count(active_only=True) < 2.3e10
    if arch == "deepseek-moe-16b":
        assert cfg.moe.n_routed == 64 and cfg.moe.top_k == 6
        assert 1.4e10 < cfg.param_count() < 1.9e10
    if arch == "mamba2-2.7b":
        assert cfg.ssm.d_state == 128
        assert 2.2e9 < cfg.param_count() < 3.2e9
    if arch == "gemma-2b":
        assert cfg.resolved_head_dim == 256 and cfg.tie_embeddings
    if arch == "qwen2-vl-7b":
        assert cfg.mrope_sections == (16, 24, 24)
