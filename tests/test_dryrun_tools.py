"""Unit tests for dry-run tooling: HLO collective parsing, input specs,
skip policy, probe-depth extrapolation arithmetic."""
import numpy as np

from repro.configs.base import INPUT_SHAPES, get_config
from repro.launch import input_specs as ispec


def test_parse_collective_bytes():
    from repro.launch.dryrun import parse_collective_bytes

    hlo = """
  %ag = bf16[2048,512]{1,0} all-gather(bf16[128,512]{1,0} %p), dimensions={0}
  %ar = f32[1024]{0} all-reduce(f32[1024]{0} %x), to_apply=%sum
  %rs = f32[64,32]{1,0} reduce-scatter(f32[1024,32]{1,0} %y), dimensions={0}
  %cp = u32[16]{0} collective-permute(u32[16]{0} %z)
  %aa = bf16[8,4]{1,0} all-to-all(bf16[8,4]{1,0} %w)
  %ags = (bf16[4,4], bf16[8,4]) all-gather-start(bf16[4,4] %q)
  %agd = bf16[8,4] all-gather-done((bf16[4,4], bf16[8,4]) %ags)
  %not_coll = f32[10]{0} add(f32[10]{0} %a, f32[10]{0} %b)
"""
    out = parse_collective_bytes(hlo)
    assert out["all-gather"] == 128 * 512 * 2 + 4 * 4 * 2   # ag + ag-start
    assert out["all-reduce"] == 1024 * 4
    assert out["reduce-scatter"] == 1024 * 32 * 4
    assert out["collective-permute"] == 16 * 4
    assert out["all-to-all"] == 8 * 4 * 2
    assert out["count"] == 6


def test_skip_policy():
    cfg = get_config("seamless-m4t-large-v2")
    assert ispec.skip_reason(cfg, INPUT_SHAPES["long_500k"]) is not None
    assert ispec.skip_reason(cfg, INPUT_SHAPES["decode_32k"]) is None
    for arch in ("mamba2-2.7b", "gemma-2b", "deepseek-v2-236b"):
        assert ispec.skip_reason(get_config(arch), INPUT_SHAPES["long_500k"]) is None


def test_window_policy():
    long = INPUT_SHAPES["long_500k"]
    dec = INPUT_SHAPES["decode_32k"]
    assert ispec.runtime_window(get_config("gemma-7b"), long) == ispec.LONG_CTX_WINDOW
    assert ispec.runtime_window(get_config("mamba2-2.7b"), long) == 0   # SSM native
    assert ispec.runtime_window(get_config("gemma-7b"), dec) == 0
    # cache capacity: ring buffer at long ctx, full otherwise
    assert ispec.cache_capacity(get_config("gemma-7b"), long) == ispec.LONG_CTX_WINDOW
    assert ispec.cache_capacity(get_config("gemma-7b"), dec) == 32768


def test_train_batch_specs_shapes():
    sh = INPUT_SHAPES["train_4k"]
    for arch, extra in [("qwen3-1.7b", None), ("qwen2-vl-7b", "image_embeds"),
                        ("seamless-m4t-large-v2", "frames")]:
        cfg = get_config(arch)
        spec = ispec.train_batch_specs(cfg, sh)
        assert spec["targets"].shape == (256, 4096)
        if extra:
            assert extra in spec
        if arch == "qwen2-vl-7b":
            assert spec["tokens"].shape == (256, 4096 - cfg.n_image_patches)
            assert spec["positions"].shape == (256, 4096, 3)


def test_decode_specs_cache_struct():
    sh = INPUT_SHAPES["decode_32k"]
    cfg = get_config("deepseek-v2-236b")
    spec = ispec.decode_specs(cfg, sh)
    c = spec["cache"]["layers"]["moe_seg"]
    # MLA latent cache, not expanded K/V
    assert "c" in c and "kr" in c and "k" not in c
    assert c["c"].shape == (59, 128, 32768, 512)
    assert c["kr"].shape == (59, 128, 32768, 64)


def test_probe_depth_extrapolation_linearity():
    """The extrapolation recovers body*L + const exactly for linear data."""
    L1, L2, Lf = 2, 4, 28
    body, const = 7.0, 3.0
    f1, f2 = const + body * L1, const + body * L2
    slope = (f2 - f1) / (L2 - L1)
    assert abs((f1 + slope * (Lf - L1)) - (const + body * Lf)) < 1e-9
