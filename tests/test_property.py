"""Property-based tests (hypothesis) on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.ema import ema_debiased_var, ema_init, ema_update
from repro.core.eat import entropy_of_logits
from repro.kernels.entropy_probe.ref import next_token_entropy_ref
from repro.kernels.flash_attention.ref import attention_ref
from repro.serving.sampler import SamplerConfig, sample

ARR = st.integers(min_value=0, max_value=2**31 - 1)


@settings(max_examples=25, deadline=None)
@given(seed=ARR, b=st.integers(1, 4), d=st.integers(4, 16),
       v=st.integers(8, 200), vpad=st.integers(0, 64))
def test_entropy_bounds(seed, b, d, v, vpad):
    """0 <= H <= log(valid vocab), regardless of logits and padding."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    h = jax.random.normal(ks[0], (b, d)) * 3
    w = jax.random.normal(ks[1], (d, v + vpad))
    ent = np.asarray(next_token_entropy_ref(h, w, v))
    assert (ent >= -1e-5).all()
    assert (ent <= np.log(v) + 1e-4).all()


@settings(max_examples=25, deadline=None)
@given(seed=ARR, alpha=st.floats(0.05, 0.9), n=st.integers(1, 60))
def test_ema_debiased_var_nonnegative_and_constant_decays(seed, alpha, n):
    rng = np.random.default_rng(seed)
    xs = rng.normal(size=n)
    stt = ema_init(1)
    for x in xs:
        stt = ema_update(stt, jnp.array([float(x)]), alpha)
    v = float(ema_debiased_var(stt, alpha)[0])
    assert v >= -1e-9
    # constant signal: the zero-init transient (M starts at 0, Alg. 1)
    # gives nonzero variance that must decay towards 0
    stc = ema_init(1)
    vals = []
    for i in range(300):
        stc = ema_update(stc, jnp.array([1.7]), alpha)
        if i in (20, 299):
            vals.append(float(ema_debiased_var(stc, alpha)[0]))
    assert vals[1] < vals[0] * 0.5 + 1e-12
    assert vals[1] < 1e-3 or alpha < 0.1


@settings(max_examples=15, deadline=None)
@given(seed=ARR)
def test_attention_kv_permutation_invariance(seed):
    """Attention over (kv, positions) must be invariant to slot permutation
    — the property ring-buffer caches rely on."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    B, Sq, Skv, H, D = 1, 3, 12, 2, 8
    q = jax.random.normal(ks[0], (B, Sq, H, D))
    k = jax.random.normal(ks[1], (B, Skv, H, D))
    v = jax.random.normal(ks[2], (B, Skv, H, D))
    qp = jnp.broadcast_to(jnp.arange(Sq) + Skv, (B, Sq)).astype(jnp.int32)
    kp = jnp.broadcast_to(jnp.arange(Skv), (B, Skv)).astype(jnp.int32)
    perm = jax.random.permutation(ks[3], Skv)
    a = attention_ref(q, k, v, qp, kp)
    b = attention_ref(q, k[:, perm], v[:, perm], qp, kp[:, perm])
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5, rtol=1e-5)


@settings(max_examples=15, deadline=None)
@given(seed=ARR, vocab=st.integers(4, 50))
def test_sampler_respects_vocab_mask(seed, vocab):
    logits = jax.random.normal(jax.random.PRNGKey(seed), (4, 64)) * 2
    tok = sample(jax.random.PRNGKey(seed + 1), logits, vocab,
                 SamplerConfig(temperature=1.0, top_p=0.9))
    assert (np.asarray(tok) < vocab).all()
    g = sample(jax.random.PRNGKey(0), logits, vocab, SamplerConfig(greedy=True))
    assert (np.asarray(g) == np.asarray(jnp.argmax(
        jnp.where(jnp.arange(64) < vocab, logits, -jnp.inf), -1))).all()


@settings(max_examples=10, deadline=None)
@given(seed=ARR)
def test_entropy_padding_invariance(seed):
    """Adding padded vocab columns must not change the entropy."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    h = jax.random.normal(ks[0], (2, 8))
    w = jax.random.normal(ks[1], (8, 33))
    e1 = next_token_entropy_ref(h, w, 33)
    wpad = jnp.pad(w, ((0, 0), (0, 31)))
    e2 = next_token_entropy_ref(h, wpad, 33)
    np.testing.assert_allclose(np.asarray(e1), np.asarray(e2), atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(seed=ARR, scale=st.floats(0.1, 5.0))
def test_entropy_of_logits_temperature_monotone(seed, scale):
    """Sharpening logits (scale > 1) cannot increase entropy."""
    logits = jax.random.normal(jax.random.PRNGKey(seed), (1, 50))
    h1 = float(entropy_of_logits(logits)[0])
    h2 = float(entropy_of_logits(logits * (1 + scale))[0])
    assert h2 <= h1 + 1e-5
