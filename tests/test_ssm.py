"""Mamba2 SSD: chunked == recurrent == split-prefill; masking; kernel sweep."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, SSMConfig
from repro.kernels.ssd_scan.kernel import ssd_scan_pallas
from repro.kernels.ssd_scan.ref import ssd_scan_ref
from repro.models import ssm
from repro.models.ssm import ssd_chunked


def make_cfg(**kw):
    base = dict(name="t", arch_type="ssm", d_model=32, vocab=16, dtype="float32",
                ssm=SSMConfig(d_state=8, head_dim=8, expand=2, chunk=4,
                              conv_width=3, n_groups=2))
    base.update(kw)
    return ModelConfig(**base)


@pytest.fixture(scope="module")
def setup():
    cfg = make_cfg()
    p = ssm.ssm_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 11, 32)) * 0.5
    return cfg, p, x


def test_chunked_equals_recurrent(setup):
    cfg, p, x = setup
    y_full, st_full = ssm.ssm_forward(p, x, cfg)
    st = ssm.ssm_state_init(cfg, 2)
    ys = []
    for t in range(x.shape[1]):
        y_t, st = ssm.ssm_step(p, x[:, t:t + 1], cfg, st)
        ys.append(y_t)
    y_seq = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_seq), atol=1e-5)
    np.testing.assert_allclose(np.asarray(st_full["ssm"]), np.asarray(st["ssm"]), atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(st_full["conv"]["x"]), np.asarray(st["conv"]["x"]), atol=1e-6
    )


def test_prefill_split_continuation(setup):
    cfg, p, x = setup
    y_full, _ = ssm.ssm_forward(p, x, cfg)
    y1, st1 = ssm.ssm_forward(p, x[:, :7], cfg)
    y2, _ = ssm.ssm_forward(p, x[:, 7:], cfg, conv_tail=st1["conv"], h0=st1["ssm"])
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate([y1, y2], 1)), np.asarray(y_full), atol=1e-5
    )


def test_left_pad_masking(setup):
    cfg, p, x = setup
    valid = jnp.ones((2, 11), bool).at[:, :3].set(False)
    xpad = x.at[:, :3].set(jax.random.normal(jax.random.PRNGKey(5), (2, 3, 32)))
    ym, stm = ssm.ssm_forward(p, xpad, cfg, valid=valid)
    yu, stu = ssm.ssm_forward(p, x[:, 3:], cfg)
    np.testing.assert_allclose(np.asarray(ym[:, 3:]), np.asarray(yu), atol=1e-5)
    np.testing.assert_allclose(np.asarray(stm["ssm"]), np.asarray(stu["ssm"]), atol=1e-5)


SSD_SWEEP = [
    # B, S, nh, hp, G, N, chunk
    (1, 16, 2, 8, 1, 8, 8),
    (2, 37, 4, 8, 2, 16, 16),
    (2, 64, 8, 16, 1, 32, 32),
    (1, 20, 6, 8, 3, 8, 4),
]


@pytest.mark.parametrize("case", SSD_SWEEP)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_kernel_sweep(case, dtype):
    B, S, nh, hp, G, N, chunk = case
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    u = (jax.random.normal(ks[0], (B, S, nh, hp)) * 0.3).astype(dtype)
    logd = (-jnp.abs(jax.random.normal(ks[1], (B, S, nh))) * 0.2).astype(jnp.float32)
    Bm = (jax.random.normal(ks[2], (B, S, G, N)) * 0.4).astype(jnp.float32)
    Cm = (jax.random.normal(ks[3], (B, S, G, N)) * 0.4).astype(jnp.float32)
    yr, hr = ssd_scan_ref(u.astype(jnp.float32), logd, Bm, Cm)
    yp, hp_ = ssd_scan_pallas(u, logd, Bm, Cm, chunk=chunk, interpret=True)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(yp, np.float32), np.asarray(yr, np.float32),
                               atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(hp_), np.asarray(hr), atol=1e-2, rtol=1e-2)


@pytest.mark.parametrize("case", SSD_SWEEP[:2])
def test_ssd_chunked_xla_matches_ref(case):
    B, S, nh, hp, G, N, chunk = case
    ks = jax.random.split(jax.random.PRNGKey(4), 4)
    u = jax.random.normal(ks[0], (B, S, nh, hp)) * 0.3
    logd = -jnp.abs(jax.random.normal(ks[1], (B, S, nh))) * 0.2
    Bm = jax.random.normal(ks[2], (B, S, G, N)) * 0.4
    Cm = jax.random.normal(ks[3], (B, S, G, N)) * 0.4
    yr, hr = ssd_scan_ref(u, logd, Bm, Cm)
    yc, hc = ssd_chunked(u, logd, Bm, Cm, chunk)
    np.testing.assert_allclose(np.asarray(yc), np.asarray(yr), atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(np.asarray(hc), np.asarray(hr), atol=2e-5, rtol=2e-5)


def test_ssd_chunked_with_initial_state():
    B, S, nh, hp, G, N = 1, 12, 2, 8, 1, 8
    ks = jax.random.split(jax.random.PRNGKey(5), 5)
    u = jax.random.normal(ks[0], (B, S, nh, hp)) * 0.3
    logd = -jnp.abs(jax.random.normal(ks[1], (B, S, nh))) * 0.2
    Bm = jax.random.normal(ks[2], (B, S, G, N)) * 0.4
    Cm = jax.random.normal(ks[3], (B, S, G, N)) * 0.4
    h0 = jax.random.normal(ks[4], (B, nh, N, hp)) * 0.2
    yr, hr = ssd_scan_ref(u, logd, Bm, Cm, h0=h0)
    yc, hc = ssd_chunked(u, logd, Bm, Cm, 4, h0=h0)
    np.testing.assert_allclose(np.asarray(yc), np.asarray(yr), atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(np.asarray(hc), np.asarray(hr), atol=2e-5, rtol=2e-5)
