"""repro-audit test suite (tools/audit + src/repro/analysis).

Two directions per pass: the seeded fixture violation under
``tests/fixtures/audit/`` IS caught (the analyzer can see), and the real
tree is clean (the contracts hold — these are the assertions CI's audit
job re-runs via ``python -m tools.audit.run --fail-on-violation``).
The lowered pass additionally gets unit fixtures for each artifact scan:
a debug-callback jaxpr, a float-widening cast, and donation mismatches in
both directions.
"""
import json
import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import PASS_NAMES, run_passes
from repro.analysis import docs_links, keys, layering, lowered, pallas_lint

REPO = Path(__file__).resolve().parent.parent
FIXTURES = Path(__file__).resolve().parent / "fixtures" / "audit"


def _rules(vs):
    return {v.rule for v in vs}


# ------------------------------------------------------------ pass 1: layering
def test_layering_catches_fixture_tree():
    r = layering.run(FIXTURES / "layer_tree")
    assert _rules(r.violations) == {
        "pure-host", "executor-only-jit", "kernels-are-leaves",
        "dispatch-only", "stays-deleted",
    }
    # the jit owner's own jit sites are not flagged
    assert not any("executor" in v.where for v in r.violations
                   if v.rule == "executor-only-jit")
    # the overlap pipeline fixture blocks twice (direct + aliased)
    assert len([v for v in r.violations if v.rule == "dispatch-only"]) == 2


def test_layering_clean_on_real_tree():
    r = layering.run(REPO / "src")
    assert r.ok, "\n".join(str(v) for v in r.violations)
    assert r.stats["modules"] > 50


def test_layering_pins_serve_step_deleted():
    """The satellite: launch/serve_step.py stays gone, and the pass is what
    enforces it."""
    assert not (REPO / "src/repro/launch/serve_step.py").exists()
    assert "repro/launch/serve_step.py" in layering.DEFAULT_RULES[
        "banned_paths"]


# ---------------------------------------------------------------- pass 3: keys
def test_keys_catches_unkeyed_knob():
    r = keys.run(FIXTURES / "keys_bad.py")
    assert r.stats["builders"] == 3
    assert len(r.violations) == 1
    v = r.violations[0]
    assert v.rule == "key-param" and "use_monitor" in v.detail
    assert "bad_chunk_program" in v.where
    # the correctly keyed builder and the KEY_EXEMPT-waived one are clean
    assert r.stats["exempt"] == ["waived"]


def test_keys_clean_on_real_executor():
    r = keys.run(REPO / "src/repro/serving/executor.py")
    assert r.ok, "\n".join(str(v) for v in r.violations)
    assert r.stats["builders"] >= 10
    assert r.stats["exempt"] == ["prefill"]


# -------------------------------------------------------------- pass 4: pallas
def test_pallas_catches_fixture_kernel():
    r = pallas_lint.run([FIXTURES / "kernels" / "bad_kernel.py"])
    assert _rules(r.violations) == {"index-map-closure", "where-mask"}
    closure = [v for v in r.violations if v.rule == "index-map-closure"]
    assert len(closure) == 1 and "idx" in closure[0].detail
    # the clean kernel in the same file contributes no violations
    assert len(r.violations) == 2


def test_pallas_clean_on_real_kernels():
    paths = sorted((REPO / "src/repro/kernels").glob("*/kernel.py"))
    assert len(paths) == 5
    r = pallas_lint.run(paths)
    assert r.ok, "\n".join(str(v) for v in r.violations)
    assert r.stats["index_maps"] > 20 and r.stats["wheres"] > 10


# ---------------------------------------------------------------- pass 5: docs
def test_docs_catches_broken_link():
    r = docs_links.run(FIXTURES / "docs_tree")
    assert len(r.violations) == 1
    assert r.violations[0].rule == "broken-link"
    assert "missing/nowhere.md" in r.violations[0].detail
    assert r.stats["links"] == 4          # good + anchor + external + broken


def test_docs_clean_on_real_tree():
    r = docs_links.run(REPO)
    assert r.ok, "\n".join(str(v) for v in r.violations)
    assert r.stats["files"] >= 4


def test_docs_shim_cli_contract():
    """tools/check_docs_links.py keeps its exit-code + summary contract."""
    cp = subprocess.run(
        [sys.executable, str(REPO / "tools/check_docs_links.py")],
        capture_output=True, text=True, cwd=REPO,
        env={**os.environ, "JAX_PLATFORMS": "cpu"},
    )
    assert cp.returncode == 0, cp.stderr
    assert "0 broken" in cp.stdout


# ------------------------------------------------------------- pass 2: lowered
def test_scan_jaxpr_flags_callback_through_cond():
    def noisy(x):
        def tap(v):
            jax.debug.print("v={v}", v=v)
            return v

        return jax.lax.cond(x.sum() > 0, tap, lambda v: v * 2, x)

    jaxpr = jax.jit(noisy).trace(jnp.ones(3)).jaxpr
    vs = lowered.scan_jaxpr(jaxpr, "unit")
    assert {v.rule for v in vs} == {"sync-point"}
    assert any("callback" in v.detail for v in vs)


def test_scan_jaxpr_flags_float_widening():
    def widen(x):
        y = x.astype(jnp.float32)
        return y @ y.T

    jaxpr = jax.jit(widen).trace(
        jax.ShapeDtypeStruct((8, 8), jnp.float16)).jaxpr
    vs = lowered.scan_jaxpr(jaxpr, "unit")
    assert {v.rule for v in vs} == {"float-widening"}
    # scalar/1-D casts are tolerated (epsilons, counters)
    clean = jax.jit(lambda s: s.astype(jnp.float32) + 1).trace(
        jax.ShapeDtypeStruct((), jnp.float16)).jaxpr
    assert lowered.scan_jaxpr(clean, "unit") == []


def test_scan_hlo_text_flags_callback_custom_call():
    text = 'custom-call target="xla_ffi_python_cpu_callback"'
    assert _rules(lowered.scan_hlo_text(text, "unit")) == {"sync-point"}
    assert lowered.scan_hlo_text("add f32[2] %a, %b", "unit") == []


def test_donation_check_both_directions():
    c = jnp.zeros((64, 64))
    x = jnp.ones((64,))

    donating = jax.jit(lambda c, x: (c.at[0].set(x), x.sum()),
                       donate_argnums=0).lower(c, x).compile()
    assert lowered.check_donation(donating, "chunk", True, "unit") == []
    flagged = lowered.check_donation(donating, "probe", False, "unit")
    assert flagged and flagged[0].rule == "donation"

    functional = jax.jit(lambda c, x: (c.at[0].set(x), x.sum())
                         ).lower(c, x).compile()
    assert lowered.check_donation(functional, "probe", False, "unit") == []
    flagged = lowered.check_donation(functional, "chunk", True, "unit")
    assert flagged and flagged[0].rule == "donation"


def test_lowered_quick_matrix_clean():
    """Two-cell smoke of the real program matrix: a self cell and a proxy
    cell trace, lower, and donation-check clean (the full 12-cell matrix
    runs in CI's audit job)."""
    r = lowered.run(quick=True)
    assert r.ok, "\n".join(str(v) for v in r.violations)
    assert r.stats["distinct_keys"] >= 10
    assert r.stats["donation_checked"] >= 5
    assert {"chunk", "shadow", "serve_step"} <= set(r.stats["families"])


# ------------------------------------------------------------------ the runner
def test_runner_cli_static_passes(tmp_path):
    out = tmp_path / "report.json"
    cp = subprocess.run(
        [sys.executable, "-m", "tools.audit.run",
         "--passes", "layering,keys,pallas,docs",
         "--fail-on-violation", "--json", str(out)],
        capture_output=True, text=True, cwd=REPO,
        env={**os.environ, "JAX_PLATFORMS": "cpu"},
    )
    assert cp.returncode == 0, cp.stdout + cp.stderr
    report = json.loads(out.read_text())
    assert report["violations"] == 0
    assert [p["name"] for p in report["passes"]] == [
        "layering", "keys", "pallas", "docs"]
    assert all(p["ok"] for p in report["passes"])


def test_run_passes_rejects_unknown_pass():
    with pytest.raises(ValueError, match="unknown pass"):
        run_passes(["nope"], REPO)
    assert set(PASS_NAMES) == {"layering", "keys", "pallas", "docs",
                               "lowered"}
