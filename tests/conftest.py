import os
import sys

# allow `pytest tests/` without PYTHONPATH=src (keeps 1 CPU device — the
# 512-device flag is ONLY set inside repro.launch.dryrun, run as its own
# process)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
