"""Block-paged KV cache tests (docs/architecture.md §Paged KV cache):

* PageAllocator invariants — alloc/free/reuse, trash-page reservation,
  exhaustion, the prompt+one-decode-page admission rule;
* append-across-page-boundary — scatter/gather through the page table
  reproduces dense ring writes exactly, including writes that straddle a
  page edge;
* gather equivalence — ``serve()`` with ``CacheConfig(kind="paged")``
  reproduces the ring path's token streams, exit steps, and EAT
  trajectories bit-for-bit on identical inputs;
* admission — a pool too small to hold every request simultaneously still
  serves the full queue because an early-exiting request's pages are
  reused by admissions in the SAME batch (and the ring cache, given the
  same physical slot budget, refuses those admissions);
* donation — the chunk program aliases the page pools in place, like the
  ring path.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core.eat import make_probe
from repro.core.monitor import ReasoningMonitor
from repro.core.stopping import EATStopper
from repro.data.synthetic import ChainTask, Tokens
from repro.models import Model
from repro.serving.cache import (
    CacheConfig,
    PAGE_TRASH,
    alloc_cache,
    alloc_paged_cache,
    cache_bytes,
    gather_pages,
    scatter_pages,
    write_slots,
)
from repro.serving.engine import EngineConfig, ReasoningEngine
from repro.serving.sampler import SamplerConfig
from repro.serving.scheduler import PageAllocator, SlotScheduler


# ------------------------------------------------------------ PageAllocator


def test_allocator_never_hands_out_trash_page():
    alloc = PageAllocator(num_pages=8, page_size=4, n_blocks=6, batch=2)
    pages = [alloc.map_block(0, b) for b in range(6)]
    assert PAGE_TRASH not in pages
    assert len(set(pages)) == 6                      # all distinct
    assert alloc.free_pages == 1


def test_allocator_free_reuse_cycle():
    alloc = PageAllocator(num_pages=6, page_size=4, n_blocks=8, batch=2)
    first = [alloc.map_block(0, b) for b in range(4)]
    assert alloc.free_pages == 1
    assert alloc.free_row(0) == 4
    assert alloc.free_pages == 5
    assert (alloc.table[0] == PAGE_TRASH).all()      # row fully unmapped
    second = [alloc.map_block(1, b) for b in range(4)]
    # LIFO free list: the freed pages back the next mapping immediately
    assert set(second) <= set(first)
    assert alloc.pages_reused == 4


def test_allocator_exhaustion_raises_with_sizing_hint():
    alloc = PageAllocator(num_pages=3, page_size=4, n_blocks=8, batch=1)
    alloc.map_block(0, 0)
    alloc.map_block(0, 1)
    with pytest.raises(RuntimeError, match="num_pages"):
        alloc.map_block(0, 2)


def test_allocator_admission_rule():
    alloc = PageAllocator(num_pages=6, page_size=8, n_blocks=8, batch=2)
    # a 12-token prompt needs 2 blocks + 1 decode page = 3 of 5 free
    assert alloc.can_admit(12)
    table_row = alloc.admit_row(0, 12, cur=20)
    assert (table_row[:2] != PAGE_TRASH).all()       # prompt blocks mapped
    assert table_row[20 // 8] != PAGE_TRASH          # decode block mapped
    assert not alloc.can_admit(25)                   # 4 needed, 2 free
    alloc.free_row(0)
    assert alloc.can_admit(25)


def test_allocator_ensure_idempotent_and_row_isolation():
    alloc = PageAllocator(num_pages=10, page_size=4, n_blocks=8, batch=3)
    alloc.ensure(0, 0, 11)
    used = alloc.pages_in_use
    alloc.ensure(0, 0, 11)                           # re-ensure: no-op
    assert alloc.pages_in_use == used
    alloc.ensure(1, 8, 11)
    # rows never share data pages
    assert set(alloc.table[0][alloc.table[0] != 0]).isdisjoint(
        set(alloc.table[1][alloc.table[1] != 0]))


# ---------------------------------------------- scatter/gather vs dense ring


def test_append_across_page_boundary_matches_dense():
    """Writes through the page table — including a write that straddles a
    page edge — gather back to exactly the dense ring layout."""
    rng = np.random.default_rng(0)
    ps, NB, P_pages, B, H, hd = 4, 4, 16, 2, 2, 3
    C = NB * ps
    alloc = PageAllocator(P_pages, ps, NB, B)
    for row in range(B):
        alloc.ensure(row, 0, C - 1)
    table = jnp.asarray(alloc.table)
    pool = jnp.zeros((P_pages, ps, H, hd), jnp.float32)
    dense = jnp.zeros((B, C, H, hd), jnp.float32)

    cur = 0
    for m in (3, 2, 5, 1):                           # 3+2 straddles slot 4
        new = jnp.asarray(rng.normal(size=(B, m, H, hd)), jnp.float32)
        slots = write_slots(jnp.asarray(cur, jnp.int32), m, C)
        assert int(slots[0]) // ps != int(slots[-1]) // ps or m == 1 or cur % ps + m <= ps
        pool = scatter_pages(pool, table, slots, new)
        dense = dense.at[:, slots].set(new)
        cur += m
    np.testing.assert_array_equal(np.asarray(gather_pages(pool, table)),
                                  np.asarray(dense))


def test_unmapped_blocks_read_trash_and_write_nothing_live():
    """A row without a mapping scatters into the trash page; a mapped row's
    gathered view is unaffected by the trash row's writes."""
    ps, NB, P_pages, B = 4, 2, 4, 2
    alloc = PageAllocator(P_pages, ps, NB, B)
    alloc.ensure(0, 0, NB * ps - 1)                  # row 0 mapped, row 1 not
    table = jnp.asarray(alloc.table)
    pool = jnp.zeros((P_pages, ps, 1, 1), jnp.float32)
    slots = jnp.arange(4, dtype=jnp.int32)
    vals = jnp.stack([jnp.full((4, 1, 1), 7.0), jnp.full((4, 1, 1), -9.0)])
    pool = scatter_pages(pool, table, slots, vals)
    out = np.asarray(gather_pages(pool, table))
    np.testing.assert_array_equal(out[0, :4, 0, 0], 7.0)   # row 0 intact
    # row 1's view is the trash page — whatever is there, it is NOT row 0's
    assert not (out[1, :4, 0, 0] == 7.0).all()


# -------------------------------------------------------- serve-level checks


def _engine(kind, *, num_pages=0, capacity=256, delta=1e9, budget=24):
    cfg = get_config("tiny")
    model = Model(cfg, attn_impl="xla")
    params = model.init(jax.random.PRNGKey(11))
    ecfg = EngineConfig(
        max_reasoning_tokens=budget, capacity=capacity,
        pad_id=Tokens.PAD, end_think_id=Tokens.END_THINK,
        newline_id=Tokens.NEWLINE, eos_id=Tokens.EOS, chunk_len=8,
        sampler=SamplerConfig(greedy=True),
        cache=CacheConfig(kind=kind, page_size=16, num_pages=num_pages),
    )
    monitor = ReasoningMonitor(
        stopper=EATStopper(alpha=0.2, delta=delta),
        probe=make_probe(Tokens.END_THINK, (Tokens.ANS,)),
        schedule="every_n", every_n=4, min_evals=1,
    )
    return ReasoningEngine(model, params, ecfg, monitor)


@pytest.fixture(scope="module")
def serve_batch():
    return ChainTask().serve_batch(np.random.default_rng(7), 6)


def test_paged_serve_identical_to_ring(serve_batch):
    """The acceptance A/B: same token streams, exit steps, and EAT
    trajectories (bit-exact) through the paged path, both delta regimes."""
    b = serve_batch
    for delta in (1e9, 0.0):
        ref = _engine("ring", delta=delta).serve(
            b["prompts"], b["prompt_len"], jax.random.PRNGKey(0),
            batch_size=4, max_tokens=24, answer_len=4, record_trace=True)
        out = _engine("paged", delta=delta).serve(
            b["prompts"], b["prompt_len"], jax.random.PRNGKey(0),
            batch_size=4, max_tokens=24, answer_len=4, record_trace=True)
        for r, o in zip(ref, out):
            assert r["n_reasoning"] == o["n_reasoning"]
            assert r["exit_reason"] == o["exit_reason"]
            assert r["ended_think"] == o["ended_think"]
            np.testing.assert_array_equal(r["reasoning_tokens"],
                                          o["reasoning_tokens"])
            np.testing.assert_array_equal(r["answer_tokens"],
                                          o["answer_tokens"])
            assert r["eat_trace"] == o["eat_trace"]   # bit-exact floats


def test_freed_pages_back_same_batch_admissions():
    """Admission through page reuse: a pool far too small to hold all fourteen
    requests' lifetimes simultaneously still serves the whole queue —
    early-exiting requests' pages are reclaimed and back the admissions in
    the same batch — while the ring cache, given the same physical slot
    budget, refuses the extra admissions."""
    b = ChainTask().serve_batch(np.random.default_rng(9), 14)
    # delta=0: every request runs its full 24-token budget, so the shared
    # ring pointer genuinely sweeps the batch-lifetime token count
    # 24 data pages * 16 slots = 384 physical slots = ring capacity 96/row
    eng = _engine("paged", num_pages=25, delta=0.0)
    out = eng.serve(b["prompts"], b["prompt_len"], jax.random.PRNGKey(0),
                    batch_size=4, max_tokens=24)
    assert len(out) == 14 and all(r["n_reasoning"] > 0 for r in out)

    # ...while a batch lifetime of 14 requests does not fit a 96-slot ring:
    need = SlotScheduler.required_capacity(b["prompts"].shape[1], 14, 4, 24)
    assert need > 96
    ring = _engine("ring", capacity=96, delta=0.0)
    with pytest.raises(RuntimeError, match="capacity"):
        ring.serve(b["prompts"], b["prompt_len"], jax.random.PRNGKey(0),
                   batch_size=4, max_tokens=24)


def test_paged_chunk_donates_pools(serve_batch):
    """Donation contract through the paged path: the chunk program aliases
    the ServeState — page pools updated in place, no per-chunk pool copy."""
    b = serve_batch
    eng = _engine("paged")
    out = eng.serve(b["prompts"], b["prompt_len"], jax.random.PRNGKey(0),
                    batch_size=4, max_tokens=24)
    assert len(out) == 6
    # the serve above built the paged chunk program; recover its key
    keys = [k for k in eng.executor._programs
            if k[0] == "chunk" and k[-1] == "paged"]
    assert keys, eng.executor._programs.keys()
    # and the allocator path exercised page reuse end-to-end is covered by
    # test_freed_pages_back_same_batch_admissions; here assert aliasing
    B = 4
    st = eng.start(jnp.asarray(b["prompts"][:B]),
                   jnp.asarray(b["prompt_len"][:B]), jax.random.PRNGKey(1),
                   capacity=16)
    from repro.serving.scheduler import PageAllocator as PA

    alloc = PA(B * 16 + 1, 16, 16, B)
    for row in range(B):
        alloc.ensure(row, 0, 255)
    paged = alloc_paged_cache(eng.model.cfg, B, 256, 16, B * 16 + 1)
    packed = eng.executor.pack_paged(paged, st.cache, alloc.table)
    st = st._replace(cache=packed)
    args = (eng.params, st, jnp.asarray(24, jnp.int32),
            jnp.asarray(8, jnp.int32))
    prog = eng.executor.chunk_program(st, True)
    compiled = prog.lower(*args).compile()
    assert compiled.memory_analysis().alias_size_in_bytes >= \
        cache_bytes(st.cache)


def test_alloc_paged_cache_validation():
    cfg = get_config("tiny")
    with pytest.raises(ValueError, match="multiple"):
        alloc_paged_cache(cfg, 2, 100, 16, 8)        # capacity % ps != 0
    with pytest.raises(ValueError, match="num_pages"):
        alloc_paged_cache(cfg, 2, 256, 16, 1)
    cache = alloc_paged_cache(cfg, 2, 256, 16, 8)
    assert cache["page_table"].shape == (2, 16)
    assert cache["layers"]["seg"]["k"].shape == (cfg.n_layers, 8, 16,
                                                 cfg.n_kv_heads,
                                                 cfg.resolved_head_dim)
    # and the ring allocator still produces the dense layout
    dense = alloc_cache(cfg, 2, 256)
    assert "page_table" not in dense
