"""MoE: capacity dispatch vs dense oracle, dropless inference, router
conservation, gradients, shard_map single-device path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, MoEConfig
from repro.models import moe
from repro.models.common import mlp_apply
from repro.sharding.partition import ShardCtx


def make_cfg(n_routed=8, top_k=2, n_shared=1, cf=2.0):
    return ModelConfig(
        name="t", arch_type="moe", d_model=32, vocab=16, d_ff=64, dtype="float32",
        moe=MoEConfig(n_routed=n_routed, n_shared=n_shared, top_k=top_k,
                      d_expert=16, capacity_factor=cf),
    )


def dense_reference(p, x, cfg):
    topw, topi, _ = moe.router_topk(p, x, cfg)
    ref = jnp.zeros_like(x)
    for e in range(cfg.moe.n_routed):
        h = jax.nn.silu(x @ p["experts"]["w_gate"][e]) * (x @ p["experts"]["w_up"][e])
        y_e = h @ p["experts"]["w_down"][e]
        w_e = jnp.where(topi == e, topw, 0.0).sum(-1)
        ref = ref + y_e * w_e[..., None]
    if cfg.moe.n_shared:
        ref = ref + mlp_apply(p["shared"], x, cfg)
    return ref


@pytest.mark.parametrize("top_k,n_routed", [(1, 4), (2, 8), (6, 16)])
def test_dispatch_matches_dense(top_k, n_routed):
    cfg = make_cfg(n_routed=n_routed, top_k=top_k)
    p = moe.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 9, 32))
    y, aux = moe.moe_apply(p, x, cfg, ShardCtx(mesh=None))
    ref = dense_reference(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=1e-5, rtol=1e-5)
    assert float(aux) > 0


def test_router_topk_weights_normalized():
    cfg = make_cfg()
    p = moe.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 5, 32))
    topw, topi, aux = moe.router_topk(p, x, cfg)
    np.testing.assert_allclose(np.asarray(topw.sum(-1)), 1.0, atol=1e-5)
    assert int(topi.max()) < cfg.moe.n_routed
    # aux loss of a perfectly uniform router ~ 1.0
    assert 0.5 < float(aux) < 4.0


def test_gradients_finite():
    cfg = make_cfg()
    p = moe.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 6, 32))

    def loss(pp):
        y, aux = moe.moe_apply(pp, x, cfg, ShardCtx(mesh=None))
        return (y ** 2).mean() + 1e-3 * aux

    g = jax.grad(loss)(p)
    for leaf in jax.tree_util.tree_leaves(g):
        assert np.isfinite(np.asarray(leaf)).all()
    # router must receive gradient (weights flow through dispatch)
    assert float(jnp.abs(g["router"]).max()) > 0


def test_capacity_truncation_drops_not_corrupts():
    """With capacity factor ~0, outputs fall back to shared expert only."""
    cfg = make_cfg(cf=2.0)
    p = moe.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    # big T so the capacity branch (not dropless) is taken: T*k > 4096
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 512, 32)) * 0.1
    y, _ = moe.moe_apply(p, x, cfg, ShardCtx(mesh=None))
    assert np.isfinite(np.asarray(y)).all()
    # ample capacity == dense reference on a subset
    ref = dense_reference(p, x[:1, :16], cfg)
    y2, _ = moe.moe_apply(p, x[:1, :16], cfg, ShardCtx(mesh=None))
    np.testing.assert_allclose(np.asarray(y2), np.asarray(ref), atol=1e-5, rtol=1e-4)


def test_capacity_rule():
    from repro.models.moe import _capacity
    assert _capacity(8, 6, 160, 1.25) == 8                  # dropless decode
    assert _capacity(65536, 6, 160, 1.25) == int(np.ceil(65536 * 6 * 1.25 / 160))
