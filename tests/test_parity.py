"""Serving-path parity: for every architecture family, logits from
(prefill all) == (prefill k + decode step-by-step), and the fused
decode+probe step == separate decode + probe, including future steps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models import Model
from repro.serving.cache import alloc_cache

FAMILIES = [
    "tiny",                         # dense GQA + qk_norm
    "tiny-moe",                     # MoE shared+routed
    "tiny-ssm",                     # Mamba2 SSD
    "zamba2-2.7b:reduced",          # hybrid
    "deepseek-v2-236b:reduced",     # MLA + MoE
    "seamless-m4t-large-v2:reduced",  # enc-dec
]


def _get(name):
    if name.endswith(":reduced"):
        return get_config(name[: -len(":reduced")]).reduced()
    return get_config(name)


@pytest.mark.parametrize("name", FAMILIES)
def test_prefill_equals_stepwise_decode(name):
    cfg = _get(name)
    model = Model(cfg, attn_impl="xla")
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    def p3(p):
        return jnp.broadcast_to(p[..., None], p.shape + (3,)) if cfg.mrope_sections else p

    kw = {}
    if cfg.arch_type == "encdec":
        kw["frames"] = 0.1 * jax.random.normal(jax.random.PRNGKey(3),
                                               (B, cfg.encoder_len, cfg.d_model))
    hidden, _ = model.prefill(params, toks, p3(pos), pos, alloc_cache(cfg, B, 24), **kw)
    ref = model.logits(params, hidden)

    cache = alloc_cache(cfg, B, 24)
    h2, cache = model.prefill(params, toks[:, :5], p3(pos[:, :5]), pos[:, :5], cache, **kw)
    outs = [model.logits(params, h2)[:, -1]]
    for t in range(5, S):
        lg, cache = model.decode_step(params, toks[:, t:t + 1], p3(pos[:, t:t + 1]),
                                      pos[:, t:t + 1], cache)
        outs.append(lg[:, -1])
    stepped = jnp.stack(outs, axis=1)
    scale = float(jnp.abs(ref[:, 4:]).max()) + 1e-9
    diff = float(jnp.abs(stepped - ref[:, 4:]).max()) / scale
    assert diff < 2e-2, (name, diff)


@pytest.mark.parametrize("name", ["tiny", "tiny-ssm", "deepseek-v2-236b:reduced"])
def test_fused_probe_equals_separate(name):
    cfg = _get(name)
    model = Model(cfg, attn_impl="xla")
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 8
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    cache = alloc_cache(cfg, B, 24)
    _, cache = model.prefill(params, toks, pos, pos, cache)

    tok = jnp.full((B, 1), 3, jnp.int32)
    p1 = jnp.full((B, 1), S, jnp.int32)
    logits_a, cache_a = model.decode_step(params, tok, p1, p1, cache)
    probe = jnp.asarray([[1, 6]] * B, jnp.int32)
    pp = jnp.broadcast_to(jnp.arange(2, dtype=jnp.int32)[None] + S + 1, (B, 2))
    eat_a = model.probe_entropy(params, probe, pp, pp, cache_a)

    pos_all = jnp.broadcast_to(jnp.arange(3, dtype=jnp.int32)[None] + S, (B, 3))
    logits_b, eat_b, cache_b = model.decode_and_probe(
        params, tok, pos_all, pos_all, cache, probe
    )
    np.testing.assert_allclose(np.asarray(logits_a), np.asarray(logits_b), atol=1e-5)
    np.testing.assert_allclose(np.asarray(eat_a), np.asarray(eat_b), atol=1e-5)
    assert int(cache_a["cur"]) == int(cache_b["cur"])

    # future decode steps agree (stale probe KV is correctly masked)
    tok2 = jnp.full((B, 1), 7, jnp.int32)
    p2 = p1 + 1
    la, ca = model.decode_step(params, tok2, p2, p2, cache_a)
    lb, cb = model.decode_step(params, tok2, p2, p2, cache_b)
    np.testing.assert_allclose(np.asarray(la), np.asarray(lb), atol=1e-5)
    p3_ = p2 + 1
    la2, _ = model.decode_step(params, tok2, p3_, p3_, ca)
    lb2, _ = model.decode_step(params, tok2, p3_, p3_, cb)
    np.testing.assert_allclose(np.asarray(la2), np.asarray(lb2), atol=1e-5)


def test_ring_buffer_decode_matches_full_cache():
    """Sliding-window decode through a ring buffer == the same window mask
    over a full cache."""
    cfg = get_config("tiny")
    import dataclasses as dc

    cfg = dc.replace(cfg, sliding_window=6)
    model = Model(cfg, attn_impl="xla")
    params = model.init(jax.random.PRNGKey(0))
    B, S = 1, 14
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    # full-capacity cache
    cache_f = alloc_cache(cfg, B, 32)
    _, cache_f = model.prefill(params, toks[:, :4], pos[:, :4], pos[:, :4], cache_f)
    # ring cache: capacity == window
    cache_r = alloc_cache(cfg, B, 6)
    _, cache_r = model.prefill(params, toks[:, :4], pos[:, :4], pos[:, :4], cache_r)
    for t in range(4, S):
        lf, cache_f = model.decode_step(params, toks[:, t:t + 1], pos[:, t:t + 1],
                                        pos[:, t:t + 1], cache_f)
        lr, cache_r = model.decode_step(params, toks[:, t:t + 1], pos[:, t:t + 1],
                                        pos[:, t:t + 1], cache_r)
        np.testing.assert_allclose(np.asarray(lf), np.asarray(lr), atol=1e-4,
                                   err_msg=f"step {t}")
