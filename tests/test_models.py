"""Model-component unit tests: RoPE/M-RoPE properties, MLA absorbed ==
expanded, rmsnorm variants, vocab padding, loss masking."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MLAConfig, ModelConfig, get_config
from repro.models import attention as att
from repro.models.common import apply_mrope, apply_rope, rmsnorm
from repro.models.model import cross_entropy_loss


def test_rope_preserves_norm():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 5, 4, 32))
    pos = jnp.broadcast_to(jnp.arange(5), (2, 5)).astype(jnp.int32)
    y = apply_rope(x, pos, 10_000.0)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1), rtol=1e-5)


def test_rope_relative_position_property():
    """<RoPE(q,m), RoPE(k,n)> depends only on m-n."""
    d = 32
    q = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, d))
    k = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 1, d))

    def score(m, n):
        qm = apply_rope(q, jnp.full((1, 1), m, jnp.int32), 10_000.0)
        kn = apply_rope(k, jnp.full((1, 1), n, jnp.int32), 10_000.0)
        return float(jnp.sum(qm * kn))

    assert abs(score(5, 3) - score(10, 8)) < 1e-4
    assert abs(score(7, 7) - score(0, 0)) < 1e-4


def test_mrope_reduces_to_rope_for_text():
    """When t==h==w positions, M-RoPE must equal ordinary RoPE."""
    d, S = 32, 6
    x = jax.random.normal(jax.random.PRNGKey(3), (1, S, 2, d))
    pos1 = jnp.broadcast_to(jnp.arange(S), (1, S)).astype(jnp.int32)
    pos3 = jnp.broadcast_to(pos1[..., None], (1, S, 3))
    a = apply_rope(x, pos1, 10_000.0)
    b = apply_mrope(x, pos3, 10_000.0, (4, 6, 6))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_mla_absorbed_equals_expanded():
    """The decode (absorbed) MLA must equal the train (expanded) MLA."""
    cfg = get_config("deepseek-v2-236b").reduced()
    p = att.mla_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    B, S = 2, 7
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model)) * 0.5
    pos = jnp.broadcast_to(jnp.arange(S), (B, S)).astype(jnp.int32)
    y_exp, (c, kr) = att.mla_self_attention(p, x, pos, pos, cfg, attn_impl="ref")
    q_nope, q_rope = att.mla_q(p, x, pos, cfg)
    y_abs = att.mla_absorbed_attend(p, q_nope, q_rope, pos, cfg, c, kr, pos,
                                    attn_impl="ref")
    np.testing.assert_allclose(np.asarray(y_exp), np.asarray(y_abs),
                               atol=2e-4, rtol=2e-4)


def test_rmsnorm_one_plus():
    x = jax.random.normal(jax.random.PRNGKey(0), (3, 8))
    w = jnp.zeros((8,))
    # gemma convention: (1 + 0) * normalized == plain normalized
    a = rmsnorm(x, w, one_plus=True)
    b = rmsnorm(x, jnp.ones((8,)), one_plus=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_vocab_padding():
    cfg = get_config("mamba2-2.7b")
    assert cfg.padded_vocab % 256 == 0
    assert cfg.padded_vocab >= cfg.vocab
    assert cfg.padded_vocab - cfg.vocab < 256
    assert get_config("gemma-2b").padded_vocab == 256_000  # already aligned


def test_cross_entropy_masking_and_padding():
    B, S, V, Vp = 2, 4, 10, 16
    logits = jax.random.normal(jax.random.PRNGKey(0), (B, S, Vp))
    targets = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, V)
    mask = jnp.ones((B, S)).at[0, 0].set(0.0)
    loss, metrics = cross_entropy_loss(logits, targets, mask, V, z_loss=0.0)
    # loss must ignore the masked position: changing its logits is a no-op
    logits2 = logits.at[0, 0].set(100.0)
    loss2, _ = cross_entropy_loss(logits2, targets, mask, V, z_loss=0.0)
    assert abs(float(loss) - float(loss2)) < 1e-5
    # padded vocab columns are excluded from the partition function
    logits3 = logits.at[..., V:].set(50.0)
    loss3, _ = cross_entropy_loss(logits3, targets, mask, V, z_loss=0.0)
    assert abs(float(loss) - float(loss3)) < 1e-5


def test_uniform_logits_ce_is_log_vocab():
    B, S, V = 1, 3, 12
    logits = jnp.zeros((B, S, V))
    targets = jnp.zeros((B, S), jnp.int32)
    loss, _ = cross_entropy_loss(logits, targets, jnp.ones((B, S)), V, z_loss=0.0)
    np.testing.assert_allclose(float(loss), np.log(V), atol=1e-5)


def test_sharding_specs_pure_logic():
    """param_pspecs is computable without real devices (AbstractMesh)."""
    from jax.sharding import PartitionSpec as P
    from repro.sharding.partition import ShardCtx, param_pspecs
    from repro.models import Model
    from repro.utils.jax_compat import make_abstract_mesh

    mesh = make_abstract_mesh((16, 16), ("data", "model"))
    ctx = ShardCtx(mesh=mesh, batch_axes=("data",))
    for arch in ["gemma-2b", "qwen3-1.7b", "deepseek-v2-236b", "mamba2-2.7b"]:
        cfg = get_config(arch)
        model = Model(cfg)
        params = jax.eval_shape(lambda m=model: m.init(jax.random.PRNGKey(0)))
        specs = param_pspecs(params, cfg, ctx)
        flat_p = jax.tree_util.tree_leaves(params)
        flat_s = jax.tree_util.tree_leaves(specs, is_leaf=lambda x: isinstance(x, P))
        assert len(flat_p) == len(flat_s)
        for leaf, spec in zip(flat_p, flat_s):
            # every sharded dim must divide
            for dim, entry in zip(leaf.shape, tuple(spec) + (None,) * 8):
                if entry == "model":
                    assert dim % 16 == 0, (arch, leaf.shape, spec)
                if entry == "data":
                    assert dim % 16 == 0, (arch, leaf.shape, spec)
