"""Host-side serving layers: request lifecycle state machine, slot
scheduler policy, and the dual-pool (generator + proxy tier) admission
gate.  Pure Python — no model, no device."""
import numpy as np
import pytest

from repro.serving.request import (
    EXIT_BUDGET,
    EXIT_EAT,
    EXIT_END_THINK,
    Request,
    RequestStatus,
)
from repro.serving.scheduler import (
    PageAllocator,
    SlotScheduler,
    pools_can_admit,
)


def _reqs(n):
    return [Request(rid=i, prompt=np.zeros(4, np.int32), prompt_len=4)
            for i in range(n)]


def test_request_lifecycle_and_exit_reasons():
    r = _reqs(1)[0]
    assert r.status is RequestStatus.QUEUED
    r.admit(slot=2)
    assert r.status is RequestStatus.PREFILLING and r.slot == 2
    r.begin_decode()
    assert r.status is RequestStatus.DECODING and not r.done
    r.record_trace(5, 1, 0.25)
    r.finish(reasoning_tokens=np.arange(3), n_reasoning=3, ended_think=False,
             eat_stop=True)
    assert r.status is RequestStatus.EXITED and r.done
    assert r.exit_reason == EXIT_EAT
    out = r.to_result()
    assert out["exit_reason"] == EXIT_EAT and out["status"] == "exited"
    assert out["eat_trace"] == [(5, 1, 0.25)]

    # reason precedence: eat > end_think > budget; budget => EXHAUSTED
    r2 = _reqs(1)[0]
    r2.admit(0); r2.begin_decode()
    r2.finish(reasoning_tokens=np.arange(2), n_reasoning=2, ended_think=True,
              eat_stop=False)
    assert r2.exit_reason == EXIT_END_THINK and r2.status is RequestStatus.EXITED

    r3 = _reqs(1)[0]
    r3.admit(0); r3.begin_decode()
    r3.finish(reasoning_tokens=np.arange(2), n_reasoning=2, ended_think=False,
              eat_stop=False)
    assert r3.exit_reason == EXIT_BUDGET and r3.status is RequestStatus.EXHAUSTED


def test_request_illegal_transitions_raise():
    r = _reqs(1)[0]
    with pytest.raises(RuntimeError, match="illegal transition"):
        r.begin_decode()                      # never admitted
    r.admit(0)
    with pytest.raises(RuntimeError, match="illegal transition"):
        r.admit(1)                            # double admission
    with pytest.raises(RuntimeError, match="illegal transition"):
        r.finish(reasoning_tokens=None, n_reasoning=0, ended_think=False,
                 eat_stop=False)              # harvest before decoding
    r.begin_decode()
    with pytest.raises(RuntimeError, match="never finished"):
        r.to_result()


def test_scheduler_fifo_and_recycling():
    reqs = _reqs(5)
    sched = SlotScheduler(reqs, batch_size=2, capacity=1000, budget=10)
    cohort = sched.start_batch()
    assert [r.rid for r in cohort] == [0, 1]
    assert sched.pending == 3 and sched.running
    for r in cohort:
        r.begin_decode()

    # slot 1 finishes -> released -> refilled FIFO with request 2
    done = sched.finished_slots(np.array([True, False]))
    assert [(s, r.rid) for s, r in done] == [(1, 1)]
    req = sched.release(1)
    req.finish(reasoning_tokens=np.arange(1), n_reasoning=1,
               ended_think=False, eat_stop=True)
    nxt = sched.admit_next(1)
    assert nxt.rid == 2 and nxt.slot == 1
    assert nxt.status is RequestStatus.PREFILLING
    assert sched.pending == 2

    # draining: empty queue admits None; fully released scheduler stops
    with pytest.raises(RuntimeError, match="still occupied"):
        sched.admit_next(0)
    sched.release(0)
    sched.release(1)
    assert sched.admit_next(0).rid == 3
    assert sched.admit_next(1).rid == 4
    sched.release(0)
    sched.release(1)
    assert sched.admit_next(0) is None
    assert not sched.running


def test_scheduler_short_queue_leaves_slots_empty():
    reqs = _reqs(2)
    sched = SlotScheduler(reqs, batch_size=4, capacity=1000, budget=10)
    cohort = sched.start_batch()
    assert len(cohort) == 2
    assert [s for s, _ in sched.bound()] == [0, 1]
    assert sched.pending == 0


def test_scheduler_capacity_guard():
    sched = SlotScheduler(_reqs(1), batch_size=1, capacity=48, budget=24)
    sched.check_capacity(10, "the initial batch")        # 34 <= 48: fine
    with pytest.raises(RuntimeError, match="capacity"):
        sched.check_capacity(30, "another admission")    # 54 > 48: wrap


# ------------------------------------------- dual-pool (proxy) admission gate
def test_pools_can_admit_gates_on_every_pool():
    """monitor="proxy" admission enters two caches; the combined gate must
    defer unless EVERY pool present covers the prompt, and skip ring caches
    (None entries) entirely."""
    gen = PageAllocator(num_pages=12, page_size=8, n_blocks=8, batch=2)
    proxy = PageAllocator(num_pages=4, page_size=8, n_blocks=8, batch=2)
    # prompt of 12 tokens: 2 blocks + 1 decode page per pool
    assert pools_can_admit(12, gen, proxy)
    # exhaust the PROXY pool only: gen alone would admit, the pair defers
    proxy.ensure(0, 0, 15)                               # 2 of 3 data pages
    assert gen.can_admit(12)
    assert not proxy.can_admit(12)
    assert not pools_can_admit(12, gen, proxy)
    # ring caches contribute no page gate
    assert pools_can_admit(12, None, None)
    assert pools_can_admit(12, gen, None)
    assert not pools_can_admit(12, None, proxy)


def test_proxy_pool_exhaustion_defers_independently_of_generator():
    """The serve-loop admission pattern with a starved PROXY pool: the
    request stays queued (deferral counted against the proxy pool, not the
    generator's) until a mid-flight exit frees the proxy row's pages — the
    same-batch reuse scenario with the blockage on the monitor side."""
    gen = PageAllocator(num_pages=32, page_size=8, n_blocks=8, batch=2)
    proxy = PageAllocator(num_pages=6, page_size=8, n_blocks=8, batch=2)
    S = 12
    # slot 0 resident in both pools: prompt blocks + a decode block
    for pool in (gen, proxy):
        pool.admit_row(0, S, cur=16)
    assert proxy.free_pages == 2                         # 5 data - 3 held
    # slot 1 freed by the generator, but the proxy pool cannot take the
    # next prompt -> the engine's deferral bookkeeping
    if not pools_can_admit(S, gen, proxy):
        for a in (gen, proxy):
            if a is not None and not a.can_admit(S):
                a.deferrals += 1
    assert (gen.deferrals, proxy.deferrals) == (0, 1)
    # slot 0's request exits mid-flight: harvest frees BOTH pools' pages,
    # and the deferred admission proceeds in the same batch
    assert gen.free_row(0) == 3 and proxy.free_row(0) == 3
    assert pools_can_admit(S, gen, proxy)
    table_row = proxy.admit_row(1, S, cur=24)
    assert (table_row[:2] != 0).all()                    # prompt mapped
    assert proxy.pages_reused > 0                        # from slot 0's frees
