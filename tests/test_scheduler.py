"""Host-side serving layers: request lifecycle state machine + slot
scheduler policy.  Pure Python — no model, no device."""
import numpy as np
import pytest

from repro.serving.request import (
    EXIT_BUDGET,
    EXIT_EAT,
    EXIT_END_THINK,
    Request,
    RequestStatus,
)
from repro.serving.scheduler import SlotScheduler


def _reqs(n):
    return [Request(rid=i, prompt=np.zeros(4, np.int32), prompt_len=4)
            for i in range(n)]


def test_request_lifecycle_and_exit_reasons():
    r = _reqs(1)[0]
    assert r.status is RequestStatus.QUEUED
    r.admit(slot=2)
    assert r.status is RequestStatus.PREFILLING and r.slot == 2
    r.begin_decode()
    assert r.status is RequestStatus.DECODING and not r.done
    r.record_trace(5, 1, 0.25)
    r.finish(reasoning_tokens=np.arange(3), n_reasoning=3, ended_think=False,
             eat_stop=True)
    assert r.status is RequestStatus.EXITED and r.done
    assert r.exit_reason == EXIT_EAT
    out = r.to_result()
    assert out["exit_reason"] == EXIT_EAT and out["status"] == "exited"
    assert out["eat_trace"] == [(5, 1, 0.25)]

    # reason precedence: eat > end_think > budget; budget => EXHAUSTED
    r2 = _reqs(1)[0]
    r2.admit(0); r2.begin_decode()
    r2.finish(reasoning_tokens=np.arange(2), n_reasoning=2, ended_think=True,
              eat_stop=False)
    assert r2.exit_reason == EXIT_END_THINK and r2.status is RequestStatus.EXITED

    r3 = _reqs(1)[0]
    r3.admit(0); r3.begin_decode()
    r3.finish(reasoning_tokens=np.arange(2), n_reasoning=2, ended_think=False,
              eat_stop=False)
    assert r3.exit_reason == EXIT_BUDGET and r3.status is RequestStatus.EXHAUSTED


def test_request_illegal_transitions_raise():
    r = _reqs(1)[0]
    with pytest.raises(RuntimeError, match="illegal transition"):
        r.begin_decode()                      # never admitted
    r.admit(0)
    with pytest.raises(RuntimeError, match="illegal transition"):
        r.admit(1)                            # double admission
    with pytest.raises(RuntimeError, match="illegal transition"):
        r.finish(reasoning_tokens=None, n_reasoning=0, ended_think=False,
                 eat_stop=False)              # harvest before decoding
    r.begin_decode()
    with pytest.raises(RuntimeError, match="never finished"):
        r.to_result()


def test_scheduler_fifo_and_recycling():
    reqs = _reqs(5)
    sched = SlotScheduler(reqs, batch_size=2, capacity=1000, budget=10)
    cohort = sched.start_batch()
    assert [r.rid for r in cohort] == [0, 1]
    assert sched.pending == 3 and sched.running
    for r in cohort:
        r.begin_decode()

    # slot 1 finishes -> released -> refilled FIFO with request 2
    done = sched.finished_slots(np.array([True, False]))
    assert [(s, r.rid) for s, r in done] == [(1, 1)]
    req = sched.release(1)
    req.finish(reasoning_tokens=np.arange(1), n_reasoning=1,
               ended_think=False, eat_stop=True)
    nxt = sched.admit_next(1)
    assert nxt.rid == 2 and nxt.slot == 1
    assert nxt.status is RequestStatus.PREFILLING
    assert sched.pending == 2

    # draining: empty queue admits None; fully released scheduler stops
    with pytest.raises(RuntimeError, match="still occupied"):
        sched.admit_next(0)
    sched.release(0)
    sched.release(1)
    assert sched.admit_next(0).rid == 3
    assert sched.admit_next(1).rid == 4
    sched.release(0)
    sched.release(1)
    assert sched.admit_next(0) is None
    assert not sched.running


def test_scheduler_short_queue_leaves_slots_empty():
    reqs = _reqs(2)
    sched = SlotScheduler(reqs, batch_size=4, capacity=1000, budget=10)
    cohort = sched.start_batch()
    assert len(cohort) == 2
    assert [s for s, _ in sched.bound()] == [0, 1]
    assert sched.pending == 0


def test_scheduler_capacity_guard():
    sched = SlotScheduler(_reqs(1), batch_size=1, capacity=48, budget=24)
    sched.check_capacity(10, "the initial batch")        # 34 <= 48: fine
    with pytest.raises(RuntimeError, match="capacity"):
        sched.check_capacity(30, "another admission")    # 54 > 48: wrap
