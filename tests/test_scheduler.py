"""Host-side serving layers: request lifecycle state machine, slot
scheduler policy, and the dual-pool (generator + proxy tier) admission
gate.  Pure Python — no model, no device."""
import numpy as np
import pytest

from repro.serving.request import (
    EXIT_BUDGET,
    EXIT_EAT,
    EXIT_END_THINK,
    Request,
    RequestStatus,
)
from repro.serving.scheduler import (
    InFlightLedger,
    PageAllocator,
    SlotScheduler,
    pools_can_admit,
)


def _reqs(n):
    return [Request(rid=i, prompt=np.zeros(4, np.int32), prompt_len=4)
            for i in range(n)]


def test_request_lifecycle_and_exit_reasons():
    r = _reqs(1)[0]
    assert r.status is RequestStatus.QUEUED
    r.admit(slot=2)
    assert r.status is RequestStatus.PREFILLING and r.slot == 2
    r.begin_decode()
    assert r.status is RequestStatus.DECODING and not r.done
    r.record_trace(5, 1, 0.25)
    r.finish(reasoning_tokens=np.arange(3), n_reasoning=3, ended_think=False,
             eat_stop=True)
    assert r.status is RequestStatus.EXITED and r.done
    assert r.exit_reason == EXIT_EAT
    out = r.to_result()
    assert out["exit_reason"] == EXIT_EAT and out["status"] == "exited"
    assert out["eat_trace"] == [(5, 1, 0.25)]

    # reason precedence: eat > end_think > budget; budget => EXHAUSTED
    r2 = _reqs(1)[0]
    r2.admit(0); r2.begin_decode()
    r2.finish(reasoning_tokens=np.arange(2), n_reasoning=2, ended_think=True,
              eat_stop=False)
    assert r2.exit_reason == EXIT_END_THINK and r2.status is RequestStatus.EXITED

    r3 = _reqs(1)[0]
    r3.admit(0); r3.begin_decode()
    r3.finish(reasoning_tokens=np.arange(2), n_reasoning=2, ended_think=False,
              eat_stop=False)
    assert r3.exit_reason == EXIT_BUDGET and r3.status is RequestStatus.EXHAUSTED


def test_request_illegal_transitions_raise():
    r = _reqs(1)[0]
    with pytest.raises(RuntimeError, match="illegal transition"):
        r.begin_decode()                      # never admitted
    r.admit(0)
    with pytest.raises(RuntimeError, match="illegal transition"):
        r.admit(1)                            # double admission
    with pytest.raises(RuntimeError, match="illegal transition"):
        r.finish(reasoning_tokens=None, n_reasoning=0, ended_think=False,
                 eat_stop=False)              # harvest before decoding
    r.begin_decode()
    with pytest.raises(RuntimeError, match="never finished"):
        r.to_result()


def test_scheduler_fifo_and_recycling():
    reqs = _reqs(5)
    sched = SlotScheduler(reqs, batch_size=2, capacity=1000, budget=10)
    cohort = sched.start_batch()
    assert [r.rid for r in cohort] == [0, 1]
    assert sched.pending == 3 and sched.running
    for r in cohort:
        r.begin_decode()

    # slot 1 finishes -> released -> refilled FIFO with request 2
    done = sched.finished_slots(np.array([True, False]))
    assert [(s, r.rid) for s, r in done] == [(1, 1)]
    req = sched.release(1)
    req.finish(reasoning_tokens=np.arange(1), n_reasoning=1,
               ended_think=False, eat_stop=True)
    nxt = sched.admit_next(1)
    assert nxt.rid == 2 and nxt.slot == 1
    assert nxt.status is RequestStatus.PREFILLING
    assert sched.pending == 2

    # draining: empty queue admits None; fully released scheduler stops
    with pytest.raises(RuntimeError, match="still occupied"):
        sched.admit_next(0)
    sched.release(0)
    sched.release(1)
    assert sched.admit_next(0).rid == 3
    assert sched.admit_next(1).rid == 4
    sched.release(0)
    sched.release(1)
    assert sched.admit_next(0) is None
    assert not sched.running


def test_scheduler_short_queue_leaves_slots_empty():
    reqs = _reqs(2)
    sched = SlotScheduler(reqs, batch_size=4, capacity=1000, budget=10)
    cohort = sched.start_batch()
    assert len(cohort) == 2
    assert [s for s, _ in sched.bound()] == [0, 1]
    assert sched.pending == 0


def test_scheduler_capacity_guard():
    sched = SlotScheduler(_reqs(1), batch_size=1, capacity=48, budget=24)
    sched.check_capacity(10, "the initial batch")        # 34 <= 48: fine
    with pytest.raises(RuntimeError, match="capacity"):
        sched.check_capacity(30, "another admission")    # 54 > 48: wrap


# ------------------------------------------- dual-pool (proxy) admission gate
def test_pools_can_admit_gates_on_every_pool():
    """monitor="proxy" admission enters two caches; the combined gate must
    defer unless EVERY pool present covers the prompt, and skip ring caches
    (None entries) entirely."""
    gen = PageAllocator(num_pages=12, page_size=8, n_blocks=8, batch=2)
    proxy = PageAllocator(num_pages=4, page_size=8, n_blocks=8, batch=2)
    # prompt of 12 tokens: 2 blocks + 1 decode page per pool
    assert pools_can_admit(12, gen, proxy)
    # exhaust the PROXY pool only: gen alone would admit, the pair defers
    proxy.ensure(0, 0, 15)                               # 2 of 3 data pages
    assert gen.can_admit(12)
    assert not proxy.can_admit(12)
    assert not pools_can_admit(12, gen, proxy)
    # ring caches contribute no page gate
    assert pools_can_admit(12, None, None)
    assert pools_can_admit(12, gen, None)
    assert not pools_can_admit(12, None, proxy)


def test_proxy_pool_exhaustion_defers_independently_of_generator():
    """The serve-loop admission pattern with a starved PROXY pool: the
    request stays queued (deferral counted against the proxy pool, not the
    generator's) until a mid-flight exit frees the proxy row's pages — the
    same-batch reuse scenario with the blockage on the monitor side."""
    gen = PageAllocator(num_pages=32, page_size=8, n_blocks=8, batch=2)
    proxy = PageAllocator(num_pages=6, page_size=8, n_blocks=8, batch=2)
    S = 12
    # slot 0 resident in both pools: prompt blocks + a decode block
    for pool in (gen, proxy):
        pool.admit_row(0, S, cur=16)
    assert proxy.free_pages == 2                         # 5 data - 3 held
    # slot 1 freed by the generator, but the proxy pool cannot take the
    # next prompt -> the engine's deferral bookkeeping
    if not pools_can_admit(S, gen, proxy):
        for a in (gen, proxy):
            if a is not None and not a.can_admit(S):
                a.deferrals += 1
    assert (gen.deferrals, proxy.deferrals) == (0, 1)
    # slot 0's request exits mid-flight: harvest frees BOTH pools' pages,
    # and the deferred admission proceeds in the same batch
    assert gen.free_row(0) == 3 and proxy.free_row(0) == 3
    assert pools_can_admit(S, gen, proxy)
    table_row = proxy.admit_row(1, S, cur=24)
    assert (table_row[:2] != 0).all()                    # prompt mapped
    assert proxy.pages_reused > 0                        # from slot 0's frees


# -------------------------------- in-flight ledger (overlapped serve loop)
def test_ledger_defer_free_waits_for_fence():
    """The overlap invariant: a harvested row's pages stay OUT of the free
    list while a fence is in flight (the dispatched chunk's captured page
    table still maps them) and re-enter it the moment that fence retires."""
    alloc = PageAllocator(num_pages=12, page_size=8, n_blocks=8, batch=2)
    led = InFlightLedger()
    led.mark_admitted(0)
    alloc.admit_row(0, 12, cur=16)                       # 3 pages
    free_before = alloc.free_pages

    f = led.open_fence()
    assert led.in_flight and not led.quiescent
    assert led.defer_free(alloc, 0) == 3
    assert led.pages_deferred == 3
    # detached: unmapped (trash) but NOT free — parked on the ledger
    assert (alloc.table[0] == 0).all()
    assert alloc.free_pages == free_before
    assert alloc.pages_in_use == 3                       # parked, not owned

    led.retire_fence(f)
    assert alloc.free_pages == free_before + 3
    assert alloc.pages_in_use == 0
    assert led.quiescent


def test_ledger_release_immediate_when_quiescent():
    """Nothing in flight -> a deferred free degenerates to a plain free
    (the final-drain boundary must hand pages straight to admissions)."""
    alloc = PageAllocator(num_pages=12, page_size=8, n_blocks=8, batch=2)
    led = InFlightLedger()
    f = led.open_fence()
    led.retire_fence(f)                                  # quiescent again
    alloc.admit_row(1, 12, cur=16)
    assert led.defer_free(alloc, 1) == 3
    assert alloc.free_pages == 11 - 1 + 1                # all data pages free
    assert led.quiescent


def test_ledger_retire_out_of_order_raises():
    led = InFlightLedger()
    led.open_fence()
    led.open_fence()
    with pytest.raises(RuntimeError, match="out of order"):
        led.retire_fence(2)                              # skips fence 1
    with pytest.raises(RuntimeError, match="out of order"):
        led.retire_fence(3)                              # never opened
    led.retire_fence(1)
    led.retire_fence(2)
    with pytest.raises(RuntimeError, match="out of order"):
        led.retire_fence(2)                              # double retire


def test_ledger_admit_into_occupied_slot_raises():
    led = InFlightLedger()
    led.mark_admitted(3)
    with pytest.raises(RuntimeError, match="still occupied"):
        led.mark_admitted(3)
    f = led.open_fence()
    led.retire_fence(f)
    led.mark_released(3, f)
    assert led.mark_admitted(3) == led.fence             # free again


def test_ledger_release_guards():
    led = InFlightLedger()
    led.mark_admitted(0)
    led.open_fence()
    with pytest.raises(RuntimeError, match="un-retired fence"):
        led.mark_released(0, 1)           # off a still-speculative snapshot
    led.retire_fence(1)
    with pytest.raises(RuntimeError, match="not occupied"):
        led.mark_released(2, 1)
    led.mark_released(0, 1)


def test_ledger_admitted_after_skip_set():
    """Rows admitted at or after fence F opened carry the previous
    occupant's data in chunk F's snapshot — the boundary harvest skips
    exactly those."""
    led = InFlightLedger()
    led.mark_admitted(0)                  # fence 0: initial cohort
    f1 = led.open_fence()
    led.mark_admitted(1)                  # while chunk 1 flies
    assert led.admitted_after(f1) == {1}
    assert led.admitted_after(f1 + 1) == set()
    led.retire_fence(f1)
    f2 = led.open_fence()
    assert led.admitted_after(f2) == set()          # slot 1 now real in f2


def test_allocator_double_free_guard():
    alloc = PageAllocator(num_pages=12, page_size=8, n_blocks=8, batch=2)
    alloc.admit_row(0, 12, cur=16)
    pages = alloc.detach_row(0)
    alloc.release_pages(pages)
    with pytest.raises(RuntimeError, match="double free"):
        alloc.release_pages(pages)                       # already free
    alloc.admit_row(0, 12, cur=16)                       # re-maps them
    with pytest.raises(RuntimeError, match="double free"):
        alloc.release_pages(alloc._owned[0][:1])         # owned, not parked


# ------------------------- overlap scheduler property (random schedules)
def _run_pipeline_schedule(ops, *, num_pages=12, batch=4, prompt=6):
    """Drive PageAllocator + InFlightLedger through an arbitrary legal
    op sequence the way serving/pipeline.py would, checking conservation
    after every step, then drain to quiescence.

    Invariants (the bugs the overlap pipeline could introduce):
      * page conservation — every data page is exactly one of {free,
        owned by a row, parked on the ledger}; no page in two places
        (double free / double map);
      * a slot is never admitted while the ledger holds it occupied;
      * the drain always reaches quiescence with every page free.
    """
    alloc = PageAllocator(num_pages=num_pages, page_size=4, n_blocks=8,
                          batch=batch)
    led = InFlightLedger()
    occupied: set[int] = set()
    grown: dict[int, int] = {}

    def check_conservation():
        free = set(alloc.free)
        owned = [p for row in alloc._owned for p in row]
        parked = [p for _, _, pages in led._pending for p in pages]
        assert len(owned) == len(set(owned)), "page owned twice"
        assert len(free) == alloc.free_pages
        all_pages = sorted(list(free) + owned + parked)
        assert all_pages == list(range(1, num_pages)), (
            free, owned, parked)

    for kind, slot, arg in ops:
        slot = slot % batch
        if kind == 0:                                    # dispatch a chunk
            led.open_fence()
        elif kind == 1 and led.in_flight:                # harvest a boundary
            led.retire_fence(led.retired + 1)
        elif kind == 2 and slot not in occupied:         # admit
            if alloc.can_admit(prompt):
                alloc.admit_row(slot, prompt, cur=arg % 32)
                led.mark_admitted(slot)
                occupied.add(slot)
                grown[slot] = prompt
        elif kind == 3 and slot in occupied:             # harvest + free
            led.mark_released(slot, led.retired)
            led.defer_free(alloc, slot)
            occupied.discard(slot)
        elif kind == 4 and slot in occupied:             # decode growth
            hi = min(grown[slot] + arg % 8, 31)
            if alloc.free_pages >= alloc.blocks_for(hi + 1):
                alloc.ensure(slot, 0, hi)
                grown[slot] = hi
        check_conservation()

    # drain: retire every open fence, free every resident row
    while led.in_flight:
        led.retire_fence(led.retired + 1)
        check_conservation()
    for slot in sorted(occupied):
        led.mark_released(slot, led.retired)
        led.defer_free(alloc, slot)
        check_conservation()
    assert led.quiescent
    assert alloc.pages_in_use == 0
    assert alloc.free_pages == num_pages - 1


def test_overlap_schedule_seeded_random():
    """Deterministic arm of the property: 20 seeded random schedules run
    everywhere (the hypothesis arm below widens the search when the
    dependency is present)."""
    for seed in range(20):
        rng = np.random.default_rng(seed)
        ops = [(int(k), int(s), int(a))
               for k, s, a in zip(rng.integers(0, 5, 200),
                                  rng.integers(0, 4, 200),
                                  rng.integers(0, 32, 200))]
        _run_pipeline_schedule(ops)


def test_overlap_schedule_property_hypothesis():
    """Property arm: arbitrary admission/exit/deferral/dispatch sequences
    never double-free a page, never admit into an occupied slot, and
    always drain to quiescence."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    op = st.tuples(st.integers(0, 4), st.integers(0, 3), st.integers(0, 31))

    @settings(max_examples=60, deadline=None)
    @given(ops=st.lists(op, max_size=120),
           num_pages=st.integers(4, 24))
    def run(ops, num_pages):
        _run_pipeline_schedule(ops, num_pages=num_pages)

    run()
