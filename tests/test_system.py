"""End-to-end system tests: training converges on the synthetic task, the
serving engine + EAT early exit run the full paper pipeline, checkpoints
round-trip, and the dry-run builder lowers on a 1-device mesh."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core.eat import make_probe
from repro.core.monitor import ReasoningMonitor
from repro.core.stopping import EATStopper
from repro.data.pipeline import train_batches
from repro.data.synthetic import ChainTask, Tokens
from repro.models import Model
from repro.serving.engine import EngineConfig, ReasoningEngine
from repro.serving.sampler import SamplerConfig
from repro.training.checkpoint import load_checkpoint, save_checkpoint
from repro.training.optimizer import AdamWConfig
from repro.training.train_loop import TrainConfig, init_train_state, make_train_step


def test_training_reduces_loss():
    cfg = get_config("tiny")
    model = Model(cfg, attn_impl="xla")
    task = ChainTask(seq_len=64)
    state = init_train_state(model, jax.random.PRNGKey(0))
    tcfg = TrainConfig(opt=AdamWConfig(lr=2e-3, warmup_steps=5, total_steps=100),
                       remat=False)
    step = jax.jit(make_train_step(model, tcfg), donate_argnums=0)
    it = train_batches(task, 16, seed=0)
    losses = []
    for i, batch in zip(range(30), it):
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] * 0.7, losses[:3] + losses[-3:]


def test_checkpoint_roundtrip():
    cfg = get_config("tiny")
    model = Model(cfg, attn_impl="xla")
    params = model.init(jax.random.PRNGKey(0))
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "ck.msgpack")
        save_checkpoint(path, params)
        restored = load_checkpoint(path, jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params))
        for a, b in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_full_eat_serving_pipeline():
    """Prompt -> reasoning with EAT monitoring -> early exit -> forced
    answer; the paper's Alg. 1 end to end (untrained model: we assert the
    mechanics, not accuracy)."""
    cfg = get_config("tiny")
    model = Model(cfg, attn_impl="xla")
    params = model.init(jax.random.PRNGKey(0))
    task = ChainTask()
    b = task.serve_batch(np.random.default_rng(0), 3)
    ecfg = EngineConfig(
        max_reasoning_tokens=40, capacity=96,
        pad_id=Tokens.PAD, end_think_id=Tokens.END_THINK,
        newline_id=Tokens.NEWLINE, eos_id=Tokens.EOS,
        sampler=SamplerConfig(temperature=1.0),
    )
    # delta huge -> stops as soon as min_evals reached: exercises early exit
    mon = ReasoningMonitor(stopper=EATStopper(alpha=0.2, delta=1e9),
                           probe=make_probe(Tokens.END_THINK, (Tokens.ANS,)),
                           newline_id=Tokens.NEWLINE, min_evals=1)
    eng = ReasoningEngine(model, params, ecfg, mon)
    st = eng.start(jnp.asarray(b["prompts"]), jnp.asarray(b["prompt_len"]),
                   jax.random.PRNGKey(1))
    st = eng.reason(st)
    # with an always-true stopper, any sequence that consumed an evaluation
    # must be flagged stopped
    stopped = np.asarray(st.monitor.stop_flag)
    evals = np.asarray(st.monitor.n_evals)
    assert (stopped == (evals >= 1)).all()
    toks, _ = eng.force_answer(st, 4)
    ans = ChainTask.extract_answer(np.asarray(toks))
    assert ans.shape == (3,)


def test_dryrun_builder_single_device():
    """The dry-run build path works with mesh=None: lower the EXECUTOR's
    serve-step program (the one the engine's chunks scan) abstractly on
    CPU."""
    from repro.launch.input_specs import decode_specs
    from repro.serving.executor import ServeStepConfig, build_serve_step_program
    from repro.configs.base import InputShape
    from repro.utils.jax_compat import cost_analysis_dict

    cfg = get_config("tiny")
    model = Model(cfg, attn_impl="xla")
    shape = InputShape("t", seq_len=32, global_batch=2, kind="decode")
    spec = decode_specs(cfg, shape)
    params_struct = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    jitted, mon = build_serve_step_program(model, ServeStepConfig(),
                                           spec["cache"], params_struct)
    lowered = jitted.lower(
        params_struct, spec["cache"], spec["token"], spec["pos1d"], mon, spec["rng"]
    )
    compiled = lowered.compile()
    assert cost_analysis_dict(compiled).get("flops", 0) > 0
