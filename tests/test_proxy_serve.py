"""Scenario suite for the proxy-EAT executor tier (``monitor="proxy"``,
paper §4.2 / Fig. 5 through the serving stack):

* bit-exactness — when the proxy IS the generator (same params), proxy-mode
  ``serve()`` reproduces self-EAT serving exactly: token streams, exit
  steps, exit reasons, forced answers, and EAT traces (exact float
  equality), through BOTH cache backends — the proxy-tier analogue of the
  paged==ring invariant in ``tests/test_paged_cache.py``;
* small proxy / large generator — a 1-layer tiny-proxy still exits every
  overthinking request before the budget (the paper's headline: a cheap
  local model stops a big black box);
* black-box contract — in proxy mode the generator executor never builds a
  probe program or a monitored chunk (program-key audit: no generator
  logits feed the exit decision); the shadow programs live in the
  ``ProxyExecutor``;
* proxy page pool — proxy-driven exits free slot AND pages that back
  same-batch admissions (the PR 3 reuse scenario with the exit decision
  originating from the proxy), including a deliberately undersized proxy
  pool gating admission independently of the generator pool;
* ``ProxyMonitor.observe_chunk`` offset regression — the standalone monitor
  must probe at the generator's stream offset, not its own chunk counter;
* CLI smoke — ``python -m repro.launch.serve --monitor proxy --requests 4``
  stays runnable (the tier-1 guard for the launcher path).
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core.eat import make_probe
from repro.core.monitor import ReasoningMonitor
from repro.core.stopping import EATStopper
from repro.data.synthetic import ChainTask, Tokens
from repro.models import Model
from repro.serving.cache import CacheConfig
from repro.serving.engine import EngineConfig, ReasoningEngine
from repro.serving.proxy import ProxyConfig, ProxyMonitor
from repro.serving.sampler import SamplerConfig


@pytest.fixture(scope="module")
def gen_model():
    model = Model(get_config("tiny"), attn_impl="xla")
    return model, model.init(jax.random.PRNGKey(11))


@pytest.fixture(scope="module")
def small_proxy():
    model = Model(get_config("tiny-proxy"), attn_impl="xla")
    return model, model.init(jax.random.PRNGKey(5))


@pytest.fixture(scope="module")
def serve_batch():
    return ChainTask().serve_batch(np.random.default_rng(7), 6)


def _engine(gen_model, *, kind="ring", delta=1e9, proxy=None, capacity=320,
            num_pages=0, budget=24):
    """Greedy tiny engine matching the paged/mesh equivalence tests; the
    generous ring capacity absorbs proxy-mode chunk overshoot (the
    generator decodes to the chunk boundary before a retract lands)."""
    model, params = gen_model
    ecfg = EngineConfig(
        max_reasoning_tokens=budget, capacity=capacity,
        pad_id=Tokens.PAD, end_think_id=Tokens.END_THINK,
        newline_id=Tokens.NEWLINE, eos_id=Tokens.EOS, chunk_len=8,
        sampler=SamplerConfig(greedy=True),
        cache=CacheConfig(kind=kind, page_size=16, num_pages=num_pages),
    )
    monitor = ReasoningMonitor(
        stopper=EATStopper(alpha=0.2, delta=delta),
        probe=make_probe(Tokens.END_THINK, (Tokens.ANS,)),
        schedule="every_n", every_n=4, min_evals=1,
    )
    return ReasoningEngine(model, params, ecfg, monitor, proxy=proxy)


# ------------------------------------------------------------ bit-exactness
def test_same_params_proxy_bit_exact_with_self_eat(gen_model, serve_batch):
    """The acceptance A/B: a proxy running the generator's own params must
    reproduce self-EAT serving bit-for-bit (greedy sampling) — exit-at-
    first-eval AND run-to-budget regimes, ring AND paged backends, exact
    float equality on the EAT traces."""
    model, params = gen_model
    b = serve_batch
    for delta in (1e9, 0.0):
        ref = _engine(gen_model, delta=delta).serve(
            b["prompts"], b["prompt_len"], jax.random.PRNGKey(0),
            batch_size=4, max_tokens=24, answer_len=4, record_trace=True)
        for kind in ("ring", "paged"):
            eng = _engine(gen_model, kind=kind, delta=delta,
                          proxy=ProxyConfig(model=model, params=params))
            out = eng.serve(b["prompts"], b["prompt_len"],
                            jax.random.PRNGKey(0), batch_size=4,
                            max_tokens=24, answer_len=4, record_trace=True)
            for r, o in zip(ref, out):
                assert r["n_reasoning"] == o["n_reasoning"], (delta, kind)
                assert r["exit_reason"] == o["exit_reason"], (delta, kind)
                assert r["ended_think"] == o["ended_think"], (delta, kind)
                np.testing.assert_array_equal(r["reasoning_tokens"],
                                              o["reasoning_tokens"])
                np.testing.assert_array_equal(r["answer_tokens"],
                                              o["answer_tokens"])
                assert r["eat_trace"] == o["eat_trace"]   # bit-exact floats


# --------------------------------------------- small proxy, large generator
def test_small_proxy_stops_large_generator(gen_model, small_proxy,
                                           serve_batch):
    """A 1-layer/32-wide proxy monitoring the 2-layer/64-wide generator
    (Fig. 5 at toy scale): every overthinking request exits via the PROXY's
    EAT signal well before the budget."""
    pm, pp = small_proxy
    b = serve_batch
    eng = _engine(gen_model, delta=1e9,
                  proxy=ProxyConfig(model=pm, params=pp))
    out = eng.serve(b["prompts"], b["prompt_len"], jax.random.PRNGKey(0),
                    batch_size=4, max_tokens=24)
    assert len(out) == 6
    for r in out:
        assert r["exit_reason"] == "eat", r
        assert r["n_reasoning"] < 24, r
        # the exit decision came from somewhere: the trace machinery must
        # carry the PROXY's evaluations
        assert r["status"] == "exited"


# ------------------------------------------------------ black-box contract
def test_generator_builds_no_probe_program_in_proxy_mode(gen_model,
                                                         small_proxy,
                                                         serve_batch):
    """Program-key audit: the black-box contract says no generator logits
    feed the exit decision — so the generator executor must never build a
    probe program or a monitored chunk; the shadow/probe programs live in
    the ProxyExecutor."""
    pm, pp = small_proxy
    b = serve_batch
    eng = _engine(gen_model, delta=1e9,
                  proxy=ProxyConfig(model=pm, params=pp))
    eng.serve(b["prompts"], b["prompt_len"], jax.random.PRNGKey(0),
              batch_size=4, max_tokens=24, answer_len=4)
    gen_keys = set(eng.executor._programs)
    assert not [k for k in gen_keys if k[0] == "probe"], gen_keys
    assert not [k for k in gen_keys if k[0] == "chunk" and k[2]], gen_keys
    # the generator DID decode (unmonitored chunks) and reconcile
    assert [k for k in gen_keys if k[0] == "chunk" and not k[2]], gen_keys
    assert [k for k in gen_keys if k[0] == "retract"], gen_keys
    # the probe work all lives in the proxy tier
    proxy_keys = set(eng.proxy_executor._programs)
    assert [k for k in proxy_keys if k[0] == "shadow"], proxy_keys
    # sanity of the audit method itself: self-EAT serving DOES build the
    # monitored chunk on the generator
    ref = _engine(gen_model, delta=1e9)
    ref.serve(b["prompts"], b["prompt_len"], jax.random.PRNGKey(0),
              batch_size=4, max_tokens=24)
    assert [k for k in ref.executor._programs if k[0] == "chunk" and k[2]]


def test_reason_refuses_proxy_mode(gen_model, serve_batch):
    """Monitored reason() has no prompt stream for the proxy to prefill —
    it must point callers at serve() instead of silently self-monitoring."""
    model, params = gen_model
    eng = _engine(gen_model, proxy=ProxyConfig(model=model, params=params))
    b = serve_batch
    st = eng.start(jnp.asarray(b["prompts"][:2]),
                   jnp.asarray(b["prompt_len"][:2]), jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="serve"):
        eng.reason(st)
    # the unmonitored path stays available (pure decode, no probes)
    st2 = eng.start(jnp.asarray(b["prompts"][:2]),
                    jnp.asarray(b["prompt_len"][:2]), jax.random.PRNGKey(0))
    st2 = eng.reason(st2, use_monitor=False, max_tokens=8)
    assert int(np.asarray(st2.n_reasoning).min()) >= 8 or \
        bool(np.asarray(st2.ended_think).any())


# ------------------------------------------------------- proxy page pooling
def test_proxy_exit_frees_pages_for_same_batch_admissions(gen_model):
    """The PR 3 reuse scenario with the exit decision originating from the
    PROXY: a generator pool far too small for fourteen request lifetimes
    still serves the whole queue because proxy-driven exits reclaim pages
    mid-batch — and the proxy tier's own pool recycles the same way."""
    model, params = gen_model
    b = ChainTask().serve_batch(np.random.default_rng(9), 14)
    eng = _engine(gen_model, kind="paged", delta=1e9, num_pages=14,
                  capacity=640,
                  proxy=ProxyConfig(model=model, params=params))
    out = eng.serve(b["prompts"], b["prompt_len"], jax.random.PRNGKey(0),
                    batch_size=4, max_tokens=24)
    assert len(out) == 14
    assert all(r["exit_reason"] == "eat" for r in out)
    # no-reuse lower bound: 14 lifetimes need >= 14 * (prompt + decode)
    # pages; 13 data pages only work because exits freed pages mid-batch
    ptier = eng._ptier
    assert ptier.alloc.pages_reused > 0
    assert ptier.alloc.peak_pages_in_use <= 13


def test_undersized_proxy_pool_still_serves_queue(gen_model):
    """The proxy pool gates admission independently: a ring generator
    (no page pressure at all) with a deliberately small PROXY pool still
    drains the queue — admissions wait for the proxy tier's harvest-time
    frees rather than failing."""
    model, params = gen_model
    b = ChainTask().serve_batch(np.random.default_rng(9), 14)
    eng = _engine(gen_model, kind="ring", delta=1e9, capacity=640,
                  proxy=ProxyConfig(
                      model=model, params=params,
                      cache=CacheConfig(kind="paged", page_size=16,
                                        num_pages=14)))
    out = eng.serve(b["prompts"], b["prompt_len"], jax.random.PRNGKey(0),
                    batch_size=4, max_tokens=24)
    assert len(out) == 14 and all(r["exit_reason"] == "eat" for r in out)
    assert eng._ptier.alloc.pages_reused > 0


def test_proxy_pool_too_small_for_one_request_fails_fast(gen_model):
    """A proxy pool that cannot hold even one prompt must raise the sizing
    error naming the PROXY pool, not hang with a forever-deferred queue."""
    model, params = gen_model
    b = ChainTask().serve_batch(np.random.default_rng(9), 3)
    eng = _engine(gen_model, kind="ring", delta=1e9, capacity=640,
                  proxy=ProxyConfig(
                      model=model, params=params,
                      cache=CacheConfig(kind="paged", page_size=4,
                                        num_pages=3)))
    with pytest.raises(RuntimeError, match="proxy|num_pages"):
        eng.serve(b["prompts"], b["prompt_len"], jax.random.PRNGKey(0),
                  batch_size=2, max_tokens=24)


# ------------------------------------- ProxyMonitor stream-offset regression
def test_proxy_monitor_probes_at_generator_offset(gen_model):
    """Regression for the observe_chunk drift: the standalone monitor used
    to recompute positions from its own chunk counter, so a row re-seeded
    mid-stream (deferred admission into a recycled slot) probed at the
    previous occupant's offset.  ``next_pos`` from the request state is
    authoritative."""
    model, params = gen_model
    monitor = ReasoningMonitor(
        stopper=EATStopper(alpha=0.2, delta=1e-3),
        probe=make_probe(Tokens.END_THINK, (Tokens.ANS,)),
        schedule="every_n", every_n=4, min_evals=1,
    )
    proxy = ProxyMonitor(model=model, params=params, monitor=monitor,
                         capacity=64)
    b = ChainTask().serve_batch(np.random.default_rng(3), 2)
    chunk = jnp.asarray(np.random.default_rng(0).integers(
        4, 40, size=(2, 6)), jnp.int32)

    ref = proxy.start(jnp.asarray(b["prompts"]), jnp.asarray(b["prompt_len"]))
    ref = proxy.observe_chunk(ref, chunk)
    ref_eat = np.asarray(ref["last_eat"])

    # same stream, but the monitor's internal counter has drifted (as after
    # a slot recycle): the generator-supplied next_pos must win
    drifted = proxy.start(jnp.asarray(b["prompts"]),
                          jnp.asarray(b["prompt_len"]))
    true_pos = drifted["next_pos"]
    drifted["next_pos"] = true_pos + 7            # stale internal counter
    out = proxy.observe_chunk(drifted, chunk, next_pos=true_pos)
    np.testing.assert_array_equal(np.asarray(out["last_eat"]), ref_eat)
    np.testing.assert_array_equal(np.asarray(out["next_pos"]),
                                  np.asarray(ref["next_pos"]))
    # and the drift reproduces without the override (the bug this pins)
    drifted2 = proxy.start(jnp.asarray(b["prompts"]),
                           jnp.asarray(b["prompt_len"]))
    drifted2["next_pos"] = drifted2["next_pos"] + 7
    bad = proxy.observe_chunk(drifted2, chunk)
    assert not np.array_equal(np.asarray(bad["last_eat"]), ref_eat)


# ----------------------------------------------------------------- CLI smoke
def test_serve_cli_proxy_smoke():
    """``launch.serve --monitor proxy --requests 4`` end to end (random
    weights): the launcher path for the proxy tier cannot rot."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--monitor", "proxy",
         "--requests", "4", "--batch", "2", "--budget", "16", "--chunk", "4",
         "--arch", "tiny", "--proxy-config", "tiny-proxy", "--local"],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert r.returncode == 0, r.stdout + r.stderr
    assert "served 4 requests" in r.stdout, r.stdout
    assert "monitor=proxy" in r.stdout, r.stdout
