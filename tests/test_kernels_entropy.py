"""entropy_probe kernel: shape/dtype sweep vs oracle + analytic cases."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.entropy_probe.kernel import entropy_probe_pallas
from repro.kernels.entropy_probe.ops import _xla_entropy, next_token_entropy
from repro.kernels.entropy_probe.ref import next_token_entropy_ref

SWEEP = [
    # B, d, Vp, vocab, block_b, block_v
    (1, 16, 64, 64, 1, 16),
    (3, 32, 257, 200, 2, 32),
    (8, 64, 1024, 1000, 8, 128),
    (5, 128, 2048, 2047, 4, 256),
]


@pytest.mark.parametrize("case", SWEEP)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pallas_matches_ref(case, dtype):
    B, d, Vp, vocab, bb, bv = case
    h = jax.random.normal(jax.random.PRNGKey(0), (B, d)).astype(dtype)
    w = (jax.random.normal(jax.random.PRNGKey(1), (d, Vp)) * 0.3).astype(dtype)
    ref = next_token_entropy_ref(h.astype(jnp.float32), w.astype(jnp.float32), vocab)
    out = entropy_probe_pallas(h, w, vocab, block_b=bb, block_v=bv, interpret=True)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=tol, rtol=tol)


@pytest.mark.parametrize("case", SWEEP)
def test_xla_matches_ref(case):
    B, d, Vp, vocab, _, bv = case
    h = jax.random.normal(jax.random.PRNGKey(2), (B, d))
    w = jax.random.normal(jax.random.PRNGKey(3), (d, Vp)) * 0.3
    ref = next_token_entropy_ref(h, w, vocab)
    out = _xla_entropy(h, w, vocab, block_v=bv)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5, rtol=1e-5)


def test_uniform_distribution_entropy():
    """Zero logits -> H = log(valid vocab) exactly."""
    for vocab, Vp in [(100, 128), (77, 77)]:
        out = next_token_entropy(jnp.zeros((2, 8)), jnp.zeros((8, Vp)), vocab, impl="xla")
        np.testing.assert_allclose(np.asarray(out), np.log(vocab), atol=1e-5)
        pal = next_token_entropy(jnp.zeros((2, 8)), jnp.zeros((8, Vp)), vocab,
                                 impl="pallas", interpret=True)
        np.testing.assert_allclose(np.asarray(pal), np.log(vocab), atol=1e-5)


def test_peaked_distribution_entropy_near_zero():
    d, Vp = 16, 256
    h = jnp.ones((1, d)) * 10
    w = jnp.zeros((d, Vp)).at[:, 7].set(10.0)
    out = next_token_entropy(h, w, Vp, impl="xla")
    assert float(out[0]) < 1e-3


def test_shift_invariance():
    """Adding a constant to all logits (h -> h + c along a direction that
    shifts every logit equally) must not change the entropy."""
    d, Vp = 8, 64
    h = jax.random.normal(jax.random.PRNGKey(4), (2, d))
    w = jax.random.normal(jax.random.PRNGKey(5), (d, Vp))
    base = next_token_entropy_ref(h, w, Vp)
    w_shift = w + 0.0
    logits_shift = 100.0  # emulate shift by adding constant row via bias trick
    h2 = jnp.concatenate([h, jnp.ones((2, 1))], axis=1)
    w2 = jnp.concatenate([w, jnp.full((1, Vp), logits_shift)], axis=0)
    out = next_token_entropy_ref(h2, w2, Vp)
    np.testing.assert_allclose(np.asarray(out), np.asarray(base), atol=1e-4)
