"""Executor-layer tests: buffer-donation audit (the KV cache must be
updated in place, not re-allocated per chunk), serve-state partition specs,
and the sharded program path on a 1x1 mesh (same math, mesh machinery on).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core.eat import make_probe
from repro.core.monitor import ReasoningMonitor
from repro.core.stopping import EATStopper
from repro.data.synthetic import ChainTask, Tokens
from repro.models import Model
from repro.serving.cache import cache_bytes
from repro.serving.engine import EngineConfig, ReasoningEngine
from repro.serving.sampler import SamplerConfig
from repro.sharding.partition import ShardCtx, serve_state_pspecs
from repro.utils.jax_compat import cost_analysis_dict, make_abstract_mesh


def _engine(ctx=None, budget=24, capacity=96):
    cfg = get_config("tiny")
    model = Model(cfg, attn_impl="xla") if ctx is None else \
        Model(cfg, ctx, attn_impl="xla")
    params = model.init(jax.random.PRNGKey(11))
    ecfg = EngineConfig(
        max_reasoning_tokens=budget, capacity=capacity,
        pad_id=Tokens.PAD, end_think_id=Tokens.END_THINK,
        newline_id=Tokens.NEWLINE, eos_id=Tokens.EOS, chunk_len=8,
        sampler=SamplerConfig(greedy=True),
    )
    monitor = ReasoningMonitor(
        stopper=EATStopper(alpha=0.2, delta=1e9),
        probe=make_probe(Tokens.END_THINK, (Tokens.ANS,)),
        schedule="every_n", every_n=4, min_evals=1,
    )
    return ReasoningEngine(model, params, ecfg, monitor)


@pytest.fixture(scope="module")
def eng_and_state():
    eng = _engine()
    b = ChainTask().serve_batch(np.random.default_rng(0), 2)
    st = eng.start(jnp.asarray(b["prompts"]), jnp.asarray(b["prompt_len"]),
                   jax.random.PRNGKey(0))
    return eng, st


# ----------------------------------------------------------- donation audit
def test_chunk_decode_donates_cache(eng_and_state):
    """Chunked decode must alias the ServeState in instead of allocating a
    second cache: peak bytes ~ 1x cache, not 2x (the satellite's
    cost_analysis assertion, via jax_compat)."""
    eng, st = eng_and_state
    budget = jnp.asarray(24, jnp.int32)
    chunk = jnp.asarray(8, jnp.int32)
    args = (eng.params, st, budget, chunk)
    donated = eng.executor.chunk_program(st, True).lower(*args).compile()
    plain = eng.executor.chunk_program(st, True, donate=False) \
        .lower(*args).compile()
    cb = cache_bytes(st.cache)

    mem_d, mem_p = donated.memory_analysis(), plain.memory_analysis()
    # the whole cache (plus the rest of the state) is donated in place ...
    assert mem_d.alias_size_in_bytes >= cb
    assert mem_p.alias_size_in_bytes == 0
    # ... which removes (at least) one full cache from the live set: peak =
    # args + temps + outputs - aliased
    def peak(m):
        return (m.argument_size_in_bytes + m.temp_size_in_bytes
                + m.output_size_in_bytes - m.alias_size_in_bytes)

    assert peak(mem_p) - peak(mem_d) >= cb
    # both variants are the same program, flops-wise
    cost = cost_analysis_dict(donated)
    assert cost.get("flops", 0) > 0
    assert cost.get("flops", 0) == cost_analysis_dict(plain).get("flops", 0)


def test_prefill_donates_cache(eng_and_state):
    eng, st = eng_and_state
    B = int(st.active.shape[0])
    prog = eng.executor._programs[("prefill", B, False, False)]
    from repro.serving.cache import alloc_cache

    prompts = jnp.zeros((B, 8), jnp.int32)
    pos1d = jnp.broadcast_to(jnp.arange(8, dtype=jnp.int32), (B, 8))
    cache = alloc_cache(eng.model.cfg, B, eng.ecfg.capacity)
    compiled = prog.lower(eng.params, prompts, pos1d, pos1d, cache).compile()
    assert compiled.memory_analysis().alias_size_in_bytes >= cache_bytes(cache)


def test_rollout_does_not_donate_cache(eng_and_state):
    """The audit's negative case: rollouts are functional reads of a live
    cache the caller keeps using — donating it would corrupt the sequence,
    so the executor must NOT alias it."""
    eng, st = eng_and_state
    toks, _ = eng.force_answer(st, 4, greedy=True)     # builds the program
    B = int(st.active.shape[0])
    prog = eng.executor._programs[("rollout", B, 4, True, "ring")]
    compiled = prog.lower(eng.params, st.cache, st.next_pos, st.last_token,
                          st.rng).compile()
    assert compiled.memory_analysis().alias_size_in_bytes < cache_bytes(st.cache)
    # and the probe stays non-committing (cache survives, same EAT twice)
    e1, e2 = eng.eval_eat_now(st), eng.eval_eat_now(st)
    np.testing.assert_allclose(np.asarray(e1), np.asarray(e2), atol=1e-6)


# ------------------------------------------------------- serve-state pspecs
def test_serve_state_pspecs_layout(eng_and_state):
    from jax.sharding import PartitionSpec as P

    _, st = eng_and_state            # B=2: divides the 2-wide data axis
    mesh = make_abstract_mesh((2, 2), ("data", "model"))
    ctx = ShardCtx(mesh=mesh)
    cfg = get_config("tiny")
    specs = serve_state_pspecs(cfg, ctx, st)
    assert specs.rng == P()
    assert specs.active == P("data")
    assert specs.out_tokens == P("data", None)
    assert specs.monitor.stop_flag == P("data")
    # tiny: n_kv_heads=2 divides model=2 -> kv heads on the model axis
    assert specs.cache["layers"]["seg"]["k"] == P(None, "data", None, "model", None)
    assert specs.cache["cur"] == P()


def test_serve_state_pspecs_b1_replicated(eng_and_state):
    from jax.sharding import PartitionSpec as P

    eng, _ = eng_and_state
    b = ChainTask().serve_batch(np.random.default_rng(1), 1)
    one = eng.start(jnp.asarray(b["prompts"]), jnp.asarray(b["prompt_len"]),
                    jax.random.PRNGKey(1))
    mesh = make_abstract_mesh((4, 2), ("data", "model"))
    specs = serve_state_pspecs(get_config("tiny"), ShardCtx(mesh=mesh), one)
    # B=1 cannot ride a 4-wide data axis: batch dims replicated, model dims kept
    assert specs.active == P(None)
    assert specs.cache["layers"]["seg"]["k"] == P(None, None, None, "model", None)


# ------------------------------------------------------------- 1x1 mesh path
def test_mesh_1x1_matches_local_exactly():
    """The sharded program path (explicit in/out shardings, donation, param
    device_put) on a trivial 1x1 mesh must be bit-identical to mesh=None —
    exercises every mesh branch of the executor inside tier-1."""
    from repro.launch.mesh import make_device_ctx

    task = ChainTask()
    b = task.serve_batch(np.random.default_rng(3), 3)

    ref_eng = _engine()
    ref = ref_eng.serve(b["prompts"], b["prompt_len"], jax.random.PRNGKey(0),
                        batch_size=2, max_tokens=24, answer_len=4)

    mesh_eng = _engine(ctx=make_device_ctx(1, 1))
    out = mesh_eng.serve(b["prompts"], b["prompt_len"], jax.random.PRNGKey(0),
                         batch_size=2, max_tokens=24, answer_len=4)

    for r, o in zip(ref, out):
        assert r["n_reasoning"] == o["n_reasoning"]
        assert r["exit_reason"] == o["exit_reason"]
        np.testing.assert_array_equal(r["reasoning_tokens"],
                                      o["reasoning_tokens"])
        np.testing.assert_array_equal(r["answer_tokens"], o["answer_tokens"])
