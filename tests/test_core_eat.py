"""Unit tests for the EAT core: EMA (Eqs. 7-8 + de-bias), stoppers
(Algs. 1-3), monitor scheduling, and the entropy helpers."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.eat import entropy_of_logits, make_probe
from repro.core.ema import ema_debiased_var, ema_init, ema_update
from repro.core.monitor import ReasoningMonitor
from repro.core.stopping import (
    ConfidenceStopper,
    EATStopper,
    TokenBudgetStopper,
    UniqueAnswerStopper,
    confidence_from_logprobs,
)


def ema_numpy(xs, alpha):
    m = v = 0.0
    for x in xs:
        m = (1 - alpha) * m + alpha * x
        v = (1 - alpha) * v + alpha * (x - m) ** 2
    return m, v


def test_ema_matches_paper_recursion():
    xs = np.random.default_rng(0).normal(2.0, 0.5, size=50)
    alpha = 0.2
    st = ema_init(1)
    for x in xs:
        st = ema_update(st, jnp.array([x]), alpha)
    m_ref, v_ref = ema_numpy(xs, alpha)
    assert abs(float(st.mean[0]) - m_ref) < 1e-6
    assert abs(float(st.var[0]) - v_ref) < 1e-6
    # de-bias: after 50 steps the correction is ~1
    v_deb = float(ema_debiased_var(st, alpha)[0])
    assert abs(v_deb - v_ref / (1 - 0.8 ** 50)) < 1e-6


def test_ema_debias_first_steps():
    st = ema_init(1)
    st = ema_update(st, jnp.array([1.0]), 0.2)
    # V after one update of constant: m=0.2, v=0.2*(1-0.2)^2... just check
    # de-bias divides by (1-(1-a)^1)=a
    assert np.isclose(float(ema_debiased_var(st, 0.2)[0]), float(st.var[0]) / 0.2)


def test_ema_freeze_inactive():
    st = ema_init(2)
    st = ema_update(st, jnp.array([1.0, 1.0]), 0.2)
    st2 = ema_update(st, jnp.array([5.0, 5.0]), 0.2,
                     active=jnp.array([True, False]))
    assert float(st2.mean[0]) != float(st.mean[0])
    assert float(st2.mean[1]) == float(st.mean[1])
    assert int(st2.count[1]) == int(st.count[1])


def test_eat_stopper_stabilization_triggers():
    """A trace that decreases then stabilizes must trigger; before
    stabilization the de-biased variance must exceed the threshold."""
    stopper = EATStopper(alpha=0.2, delta=1e-3)
    trace = [3.0, 2.5, 2.0, 1.2, 0.5] + [0.1] * 40
    st = stopper.init(1)
    fired_at = None
    for i, x in enumerate(trace):
        st = stopper.update(st, jnp.array([x]))
        if bool(stopper.should_stop(st)[0]) and fired_at is None:
            fired_at = i
    assert fired_at is not None and fired_at >= 5          # not during descent
    # noisy trace must NOT trigger
    rng = np.random.default_rng(1)
    st = stopper.init(1)
    fired = False
    for x in 2.0 + rng.normal(0, 0.5, 40):
        st = stopper.update(st, jnp.array([float(x)]))
        fired |= bool(stopper.should_stop(st)[0])
    assert not fired


def test_smaller_delta_stops_later():
    trace = np.concatenate([np.linspace(3, 0.2, 12), 0.2 + 0.01 * np.random.default_rng(0).normal(size=60)])

    def exit_step(delta):
        stp = EATStopper(alpha=0.2, delta=delta)
        st = stp.init(1)
        for i, x in enumerate(trace):
            st = stp.update(st, jnp.array([float(x)]))
            if bool(stp.should_stop(st)[0]):
                return i
        return len(trace)

    assert exit_step(1e-2) <= exit_step(1e-3) <= exit_step(1e-5)


def test_token_budget_stopper():
    stp = TokenBudgetStopper(budget=10)
    st = stp.init(2)
    for _ in range(4):
        st = stp.update(st, jnp.array([3, 1]), active=jnp.array([True, True]))
    stop = stp.should_stop(st)
    assert bool(stop[0]) and not bool(stop[1])


def test_unique_answer_stopper():
    stp = UniqueAnswerStopper(k=4, max_unique=1)
    st = stp.init(2)
    answers = jnp.array([[3, 3, 3, 3], [1, 2, 3, 3]])
    st = stp.update(st, answers)
    assert bool(stp.should_stop(st)[0])
    assert not bool(stp.should_stop(st)[1])
    assert int(st.n_unique[1]) == 3


def test_confidence_helper():
    lp = jnp.log(jnp.array([[0.5, 0.5, 0.5]]))
    c = confidence_from_logprobs(lp)
    assert np.isclose(float(c[0]), 0.5)


def test_monitor_newline_scheduling():
    mon = ReasoningMonitor(stopper=EATStopper(alpha=0.2, delta=1e-4),
                           probe=make_probe(1, (6,)), newline_id=2, min_evals=2)
    st = mon.init(2)
    tok = jnp.array([2, 5])          # seq0 newline, seq1 not
    due = mon.due(st, tok)
    assert bool(due[0]) and not bool(due[1])
    active = jnp.ones(2, bool)
    st = mon.update(st, jnp.array([1.0, 1.0]), due, active)
    assert int(st.n_evals[0]) == 1 and int(st.n_evals[1]) == 0


def test_monitor_min_evals_blocks_stop():
    mon = ReasoningMonitor(stopper=EATStopper(alpha=0.5, delta=1e3),  # huge delta
                           probe=make_probe(1), newline_id=2, min_evals=3)
    st = mon.init(1)
    active = jnp.ones(1, bool)
    due = jnp.ones(1, bool)
    st = mon.update(st, jnp.array([1.0]), due, active)
    assert not bool(st.stop_flag[0])          # only 1 eval < min_evals
    st = mon.update(st, jnp.array([1.0]), due, active)
    st = mon.update(st, jnp.array([1.0]), due, active)
    assert bool(st.stop_flag[0])


def test_entropy_of_logits_bounds():
    logits = jnp.zeros((2, 100))
    h = entropy_of_logits(logits)
    assert np.allclose(np.asarray(h), np.log(100), atol=1e-5)
    peaked = jnp.zeros((1, 100)).at[0, 3].set(100.0)
    assert float(entropy_of_logits(peaked)[0]) < 1e-3
    # padded vocab exclusion
    h2 = entropy_of_logits(jnp.zeros((1, 128)), vocab=100)
    assert np.isclose(float(h2[0]), np.log(100), atol=1e-5)


def test_giveup_stopper_fires_on_stall_not_on_stabilize():
    from repro.core.stopping import GiveUpStopper

    stp = GiveUpStopper(alpha=0.2, ceiling=0.05, patience=5, min_evals=4)
    # noisy high trace (unsolvable regime) -> gives up
    rng = np.random.default_rng(0)
    st = stp.init(1)
    fired = None
    for i, x in enumerate(2.0 + rng.normal(0, 0.6, 40)):
        st = stp.update(st, jnp.array([float(x)]))
        if bool(stp.should_stop(st)[0]) and fired is None:
            fired = i
    assert fired is not None and fired >= stp.min_evals + stp.patience - 2

    # stabilizing trace -> never gives up
    st = stp.init(1)
    fired = False
    trace = list(np.linspace(3, 0.05, 8)) + [0.05] * 30
    for x in trace:
        st = stp.update(st, jnp.array([float(x)]))
        fired |= bool(stp.should_stop(st)[0])
    assert not fired
