"""Serving-engine behaviour on a tiny (untrained) model: batching, early
exit mechanics, probe non-commitment, rollout shapes, proxy monitor."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core.eat import make_probe
from repro.core.monitor import ReasoningMonitor
from repro.core.stopping import EATStopper
from repro.data.synthetic import ChainTask, Tokens
from repro.models import Model
from repro.serving.engine import EngineConfig, ReasoningEngine
from repro.serving.proxy import ProxyMonitor
from repro.serving.sampler import SamplerConfig


@pytest.fixture(scope="module")
def engine():
    cfg = get_config("tiny")
    model = Model(cfg, attn_impl="xla")
    params = model.init(jax.random.PRNGKey(0))
    ecfg = EngineConfig(
        max_reasoning_tokens=48, capacity=128,
        pad_id=Tokens.PAD, end_think_id=Tokens.END_THINK,
        newline_id=Tokens.NEWLINE, eos_id=Tokens.EOS,
        sampler=SamplerConfig(temperature=1.0, top_p=0.95),
    )
    monitor = ReasoningMonitor(
        stopper=EATStopper(alpha=0.2, delta=1e-6),
        probe=make_probe(Tokens.END_THINK, (Tokens.ANS,)),
        newline_id=Tokens.NEWLINE,
    )
    return ReasoningEngine(model, params, ecfg, monitor)


@pytest.fixture(scope="module")
def batch():
    task = ChainTask()
    return task.serve_batch(np.random.default_rng(0), 4)


def test_start_and_reason(engine, batch):
    st = engine.start(jnp.asarray(batch["prompts"]), jnp.asarray(batch["prompt_len"]),
                      jax.random.PRNGKey(1))
    assert st.active.all()
    st = engine.reason(st, max_tokens=32)
    assert int(st.n_reasoning.max()) <= 33
    # all sequences terminated one way or another
    assert (~np.asarray(st.active)).all() or int(st.n_reasoning.max()) >= 32


def test_probe_does_not_commit(engine, batch):
    st = engine.start(jnp.asarray(batch["prompts"]), jnp.asarray(batch["prompt_len"]),
                      jax.random.PRNGKey(2))
    pos_before = np.asarray(st.cache["pos"]).copy()
    cur_before = int(st.cache["cur"])
    eat1 = engine.eval_eat_now(st)
    eat2 = engine.eval_eat_now(st)
    np.testing.assert_array_equal(np.asarray(st.cache["pos"]), pos_before)
    assert int(st.cache["cur"]) == cur_before
    np.testing.assert_allclose(np.asarray(eat1), np.asarray(eat2), atol=1e-6)
    assert (np.asarray(eat1) >= 0).all()


def test_force_answer_rollouts(engine, batch):
    st = engine.start(jnp.asarray(batch["prompts"]), jnp.asarray(batch["prompt_len"]),
                      jax.random.PRNGKey(3))
    toks, lps = engine.force_answer(st, 6)
    assert toks.shape == (4, 6) and lps.shape == (4, 6)
    assert (np.asarray(lps) <= 1e-6).all()
    rolls = engine.rollout_answers(st, k=3, n_tokens=6, rng=jax.random.PRNGKey(4))
    assert rolls.shape == (3, 4, 6)
    # greedy rollouts are deterministic
    g1, _ = engine.force_answer(st, 6, greedy=True)
    g2, _ = engine.force_answer(st, 6, greedy=True)
    np.testing.assert_array_equal(np.asarray(g1), np.asarray(g2))


def test_exited_sequences_freeze(engine, batch):
    st = engine.start(jnp.asarray(batch["prompts"]), jnp.asarray(batch["prompt_len"]),
                      jax.random.PRNGKey(5))
    st = st._replace(active=jnp.array([True, False, True, False]))
    n_before = np.asarray(st.n_reasoning).copy()
    st2 = engine._decode_fn(engine.params, st)
    n_after = np.asarray(st2.n_reasoning)
    assert n_after[0] == n_before[0] + 1 and n_after[2] == n_before[2] + 1
    assert n_after[1] == n_before[1] and n_after[3] == n_before[3]
    assert int(st2.last_token[1]) == Tokens.PAD


def test_trace_records(engine, batch):
    st = engine.start(jnp.asarray(batch["prompts"]), jnp.asarray(batch["prompt_len"]),
                      jax.random.PRNGKey(6))
    st, trace = engine.reason_with_trace(st, max_tokens=24, rollout_k=2,
                                         rollout_len=4,
                                         answer_extract=ChainTask.extract_answer)
    for rec in trace:
        assert rec["eat"].shape == (4,)
        assert np.isfinite(rec["eat"]).all()
        assert rec["rollouts"].shape == (2, 4, 4)
        assert "ema_var" in rec


def test_chunked_matches_per_token_budget(engine, batch):
    """The chunked device loop and the legacy host loop enforce the same
    budget/exit semantics (stochastic sampling aside)."""
    st = engine.start(jnp.asarray(batch["prompts"]), jnp.asarray(batch["prompt_len"]),
                      jax.random.PRNGKey(8))
    st = engine.reason(st, max_tokens=24, use_monitor=False, chunk_len=7)
    assert not bool(np.asarray(st.active).any())
    assert (np.asarray(st.n_reasoning) <= 24).all()
    assert (np.asarray(st.out_len) == np.asarray(st.n_reasoning)).all()


def _greedy_engine(every_n=4, delta=1e9, max_tokens=24, capacity=256):
    """Deterministic engine: greedy sampling + stop at the first EAT eval
    (delta huge), scheduled every `every_n` tokens."""
    cfg = get_config("tiny")
    model = Model(cfg, attn_impl="xla")
    params = model.init(jax.random.PRNGKey(11))
    ecfg = EngineConfig(
        max_reasoning_tokens=max_tokens, capacity=capacity,
        pad_id=Tokens.PAD, end_think_id=Tokens.END_THINK,
        newline_id=Tokens.NEWLINE, eos_id=Tokens.EOS, chunk_len=8,
        sampler=SamplerConfig(greedy=True),
    )
    monitor = ReasoningMonitor(
        stopper=EATStopper(alpha=0.2, delta=delta),
        probe=make_probe(Tokens.END_THINK, (Tokens.ANS,)),
        schedule="every_n", every_n=every_n, min_evals=1,
    )
    return ReasoningEngine(model, params, ecfg, monitor)


def test_serve_slot_recycling():
    """Continuous batching: a sequence exiting early frees its slot and an
    admitted prompt completes correctly in it (identical tokens to serving
    that prompt alone, since decoding is greedy)."""
    eng = _greedy_engine()
    task = ChainTask()
    b = task.serve_batch(np.random.default_rng(7), 5)
    results = eng.serve(b["prompts"], b["prompt_len"], jax.random.PRNGKey(0),
                        batch_size=2, max_tokens=24, answer_len=4)
    assert all(r is not None for r in results)
    assert [r["request"] for r in results] == list(range(5))
    # every sequence stopped at the first due EAT eval (delta huge) unless
    # it emitted </think> first
    for r in results:
        assert r["ended_think"] or r["n_reasoning"] <= 5
        assert r["answer_tokens"].shape == (4,)
        assert len(r["reasoning_tokens"]) == r["n_reasoning"]

    # request 3 was only ever served in a recycled slot (batch_size=2);
    # serving it alone must produce the identical greedy token stream
    solo_state = eng.start(jnp.asarray(b["prompts"][3:4]),
                           jnp.asarray(b["prompt_len"][3:4]),
                           jax.random.PRNGKey(99))
    solo_state = eng.reason(solo_state, max_tokens=24)
    solo_tokens = np.asarray(solo_state.out_tokens)[0, :int(solo_state.out_len[0])]
    np.testing.assert_array_equal(results[3]["reasoning_tokens"], solo_tokens)
    solo_ans, _ = eng.force_answer(solo_state, 4, greedy=True)
    np.testing.assert_array_equal(results[3]["answer_tokens"],
                                  np.asarray(solo_ans)[0])


def test_inactive_ride_along_preserves_rollout():
    """A row that exits while its batch keeps decoding must produce the same
    forced answer afterwards: its ride-along KV writes carry pos=-1, so no
    later attention query can see them."""
    eng = _greedy_engine(every_n=64, max_tokens=16)  # monitor never fires
    task = ChainTask()
    b = task.serve_batch(np.random.default_rng(5), 2)
    st = eng.start(jnp.asarray(b["prompts"]), jnp.asarray(b["prompt_len"]),
                   jax.random.PRNGKey(3))
    st = st._replace(active=jnp.array([False, True]))   # row 0 exited
    before, _ = eng.force_answer(st, 6, greedy=True)
    n0 = int(st.n_reasoning[0])         # reason() donates st's buffers
    st2 = eng.reason(st, max_tokens=16)                 # row 1 rides 15 steps
    assert int(st2.n_reasoning[0]) == n0
    after, _ = eng.force_answer(st2, 6, greedy=True)
    np.testing.assert_array_equal(np.asarray(before)[0], np.asarray(after)[0])


def test_serve_capacity_guard():
    """serve() refuses to wrap the shared cache ring instead of silently
    overwriting live KV rows."""
    eng = _greedy_engine(every_n=64, max_tokens=24, capacity=48)
    task = ChainTask()
    b = task.serve_batch(np.random.default_rng(4), 4)
    with pytest.raises(RuntimeError, match="capacity"):
        eng.serve(b["prompts"], b["prompt_len"], jax.random.PRNGKey(0),
                  batch_size=2, max_tokens=24)


def test_admit_preserves_resident_rows():
    """Admitting into a freed slot must not perturb still-active rows: the
    other row's greedy continuation is unchanged by the merge."""
    eng = _greedy_engine(every_n=64, max_tokens=16)  # monitor never fires
    task = ChainTask()
    b = task.serve_batch(np.random.default_rng(9), 3)
    st = eng.start(jnp.asarray(b["prompts"][:2]), jnp.asarray(b["prompt_len"][:2]),
                   jax.random.PRNGKey(1))
    ref = eng.reason(st, max_tokens=16)   # row 1's undisturbed rollout

    # reason() donated st's buffers — rebuild the identical state (greedy
    # engine + same PRNGKey => bit-identical prefill) before admitting
    st = eng.start(jnp.asarray(b["prompts"][:2]), jnp.asarray(b["prompt_len"][:2]),
                   jax.random.PRNGKey(1))
    one = eng.start(jnp.asarray(b["prompts"][2:3]), jnp.asarray(b["prompt_len"][2:3]),
                    jax.random.PRNGKey(2))
    st2 = eng._admit(st, one, 0)          # replace row 0 mid-flight
    st2 = eng.reason(st2, max_tokens=16)
    np.testing.assert_array_equal(np.asarray(ref.out_tokens)[1],
                                  np.asarray(st2.out_tokens)[1])
    assert int(st2.n_reasoning[1]) == int(ref.n_reasoning[1])


def test_trace_records_final_budget_eval(engine, batch):
    """The evaluation point at the budget-th token must appear in the trace
    even though the chunk latches active=False in that same device step
    (App. H records every due point of the full-length chain)."""
    cfg = get_config("tiny")
    model = Model(cfg, attn_impl="xla")
    params = model.init(jax.random.PRNGKey(21))
    ecfg = EngineConfig(
        max_reasoning_tokens=9, capacity=128,
        pad_id=Tokens.PAD, end_think_id=Tokens.END_THINK,
        newline_id=Tokens.NEWLINE, eos_id=Tokens.EOS,
        sampler=SamplerConfig(temperature=1.0, top_p=0.95),
    )
    monitor = ReasoningMonitor(
        stopper=EATStopper(alpha=0.2, delta=0.0),
        probe=make_probe(Tokens.END_THINK, (Tokens.ANS,)),
        schedule="every_n", every_n=3,
    )
    eng = ReasoningEngine(model, params, ecfg, monitor)
    task = ChainTask()
    b = task.serve_batch(np.random.default_rng(2), 4)
    st = eng.start(jnp.asarray(b["prompts"]), jnp.asarray(b["prompt_len"]),
                   jax.random.PRNGKey(22))
    st, trace = eng.reason_with_trace(st, max_tokens=9)
    assert trace
    survived = ~np.asarray(st.ended_think) & (np.asarray(st.n_reasoning) == 9)
    assert survived.any()          # seeded: some rows reach the full budget
    last = trace[-1]
    assert (last["n_tokens"][survived] == 9).all()
    assert last["due"][survived].all()


def test_proxy_monitor_stream():
    cfg = get_config("tiny")
    model = Model(cfg, attn_impl="xla")
    params = model.init(jax.random.PRNGKey(7))
    mon = ReasoningMonitor(
        stopper=EATStopper(alpha=0.3, delta=1e-9),
        probe=make_probe(Tokens.END_THINK, (Tokens.ANS,)),
        newline_id=Tokens.NEWLINE,
    )
    proxy = ProxyMonitor(model=model, params=params, monitor=mon, capacity=64)
    task = ChainTask()
    b = task.serve_batch(np.random.default_rng(1), 2)
    st = proxy.start(jnp.asarray(b["prompts"]), jnp.asarray(b["prompt_len"]))
    chunk = jnp.full((2, 5), Tokens.STEP, jnp.int32)
    st = proxy.observe_chunk(st, chunk)
    assert np.isfinite(np.asarray(st["last_eat"])).all()
    assert len(st["probe_seconds"]) == 1
    st = proxy.observe_chunk(st, chunk)
    assert int(st["next_pos"][0]) == int(b["prompt_len"][0]) + 10
