"""Serving-engine behaviour on a tiny (untrained) model: batching, early
exit mechanics, probe non-commitment, rollout shapes, proxy monitor."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core.eat import make_probe
from repro.core.monitor import ReasoningMonitor
from repro.core.stopping import EATStopper
from repro.data.synthetic import ChainTask, Tokens
from repro.models import Model
from repro.serving.engine import EngineConfig, ReasoningEngine
from repro.serving.proxy import ProxyMonitor
from repro.serving.sampler import SamplerConfig


@pytest.fixture(scope="module")
def engine():
    cfg = get_config("tiny")
    model = Model(cfg, attn_impl="xla")
    params = model.init(jax.random.PRNGKey(0))
    ecfg = EngineConfig(
        max_reasoning_tokens=48, capacity=128,
        pad_id=Tokens.PAD, end_think_id=Tokens.END_THINK,
        newline_id=Tokens.NEWLINE, eos_id=Tokens.EOS,
        sampler=SamplerConfig(temperature=1.0, top_p=0.95),
    )
    monitor = ReasoningMonitor(
        stopper=EATStopper(alpha=0.2, delta=1e-6),
        probe=make_probe(Tokens.END_THINK, (Tokens.ANS,)),
        newline_id=Tokens.NEWLINE,
    )
    return ReasoningEngine(model, params, ecfg, monitor)


@pytest.fixture(scope="module")
def batch():
    task = ChainTask()
    return task.serve_batch(np.random.default_rng(0), 4)


def test_start_and_reason(engine, batch):
    st = engine.start(jnp.asarray(batch["prompts"]), jnp.asarray(batch["prompt_len"]),
                      jax.random.PRNGKey(1))
    assert st.active.all()
    st = engine.reason(st, max_tokens=32)
    assert int(st.n_reasoning.max()) <= 33
    # all sequences terminated one way or another
    assert (~np.asarray(st.active)).all() or int(st.n_reasoning.max()) >= 32


def test_probe_does_not_commit(engine, batch):
    st = engine.start(jnp.asarray(batch["prompts"]), jnp.asarray(batch["prompt_len"]),
                      jax.random.PRNGKey(2))
    pos_before = np.asarray(st.cache["pos"]).copy()
    cur_before = int(st.cache["cur"])
    eat1 = engine.eval_eat_now(st)
    eat2 = engine.eval_eat_now(st)
    np.testing.assert_array_equal(np.asarray(st.cache["pos"]), pos_before)
    assert int(st.cache["cur"]) == cur_before
    np.testing.assert_allclose(np.asarray(eat1), np.asarray(eat2), atol=1e-6)
    assert (np.asarray(eat1) >= 0).all()


def test_force_answer_rollouts(engine, batch):
    st = engine.start(jnp.asarray(batch["prompts"]), jnp.asarray(batch["prompt_len"]),
                      jax.random.PRNGKey(3))
    toks, lps = engine.force_answer(st, 6)
    assert toks.shape == (4, 6) and lps.shape == (4, 6)
    assert (np.asarray(lps) <= 1e-6).all()
    rolls = engine.rollout_answers(st, k=3, n_tokens=6, rng=jax.random.PRNGKey(4))
    assert rolls.shape == (3, 4, 6)
    # greedy rollouts are deterministic
    g1, _ = engine.force_answer(st, 6, greedy=True)
    g2, _ = engine.force_answer(st, 6, greedy=True)
    np.testing.assert_array_equal(np.asarray(g1), np.asarray(g2))


def test_exited_sequences_freeze(engine, batch):
    st = engine.start(jnp.asarray(batch["prompts"]), jnp.asarray(batch["prompt_len"]),
                      jax.random.PRNGKey(5))
    st = st._replace(active=jnp.array([True, False, True, False]))
    n_before = np.asarray(st.n_reasoning).copy()
    st2 = engine._decode_fn(engine.params, st)
    n_after = np.asarray(st2.n_reasoning)
    assert n_after[0] == n_before[0] + 1 and n_after[2] == n_before[2] + 1
    assert n_after[1] == n_before[1] and n_after[3] == n_before[3]
    assert int(st2.last_token[1]) == Tokens.PAD


def test_trace_records(engine, batch):
    st = engine.start(jnp.asarray(batch["prompts"]), jnp.asarray(batch["prompt_len"]),
                      jax.random.PRNGKey(6))
    st, trace = engine.reason_with_trace(st, max_tokens=24, rollout_k=2,
                                         rollout_len=4,
                                         answer_extract=ChainTask.extract_answer)
    for rec in trace:
        assert rec["eat"].shape == (4,)
        assert np.isfinite(rec["eat"]).all()
        assert rec["rollouts"].shape == (2, 4, 4)
        assert "ema_var" in rec


def test_proxy_monitor_stream():
    cfg = get_config("tiny")
    model = Model(cfg, attn_impl="xla")
    params = model.init(jax.random.PRNGKey(7))
    mon = ReasoningMonitor(
        stopper=EATStopper(alpha=0.3, delta=1e-9),
        probe=make_probe(Tokens.END_THINK, (Tokens.ANS,)),
        newline_id=Tokens.NEWLINE,
    )
    proxy = ProxyMonitor(model=model, params=params, monitor=mon, capacity=64)
    task = ChainTask()
    b = task.serve_batch(np.random.default_rng(1), 2)
    st = proxy.start(jnp.asarray(b["prompts"]), jnp.asarray(b["prompt_len"]))
    chunk = jnp.full((2, 5), Tokens.STEP, jnp.int32)
    st = proxy.observe_chunk(st, chunk)
    assert np.isfinite(np.asarray(st["last_eat"])).all()
    assert len(st["probe_seconds"]) == 1
    st = proxy.observe_chunk(st, chunk)
    assert int(st["next_pos"][0]) == int(b["prompt_len"][0]) + 10
