"""Masking math for the sampler's top-k / top-p / min-p filters."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.sampler import SamplerConfig, filter_logits, sample


def _lf(rows):
    return jnp.asarray(np.array(rows, np.float32))


def test_top_k_keeps_exactly_k():
    lf = _lf([[0.0, 3.0, 1.0, 2.0, -1.0]])
    out = filter_logits(lf, SamplerConfig(top_k=2, top_p=1.0))
    kept = np.isfinite(np.asarray(out))[0]
    np.testing.assert_array_equal(kept, [False, True, False, True, False])
    # surviving logits pass through unchanged
    assert float(out[0, 1]) == 3.0 and float(out[0, 3]) == 2.0


def test_top_k_off_and_oversized_are_noops():
    lf = _lf([[0.0, 3.0, 1.0]])
    for k in (0, 3, 10):
        out = filter_logits(lf, SamplerConfig(top_k=k, top_p=1.0))
        assert np.isfinite(np.asarray(out)).all()


def test_top_k_is_per_row():
    lf = _lf([[5.0, 1.0, 0.0], [0.0, 1.0, 5.0]])
    out = np.asarray(filter_logits(lf, SamplerConfig(top_k=1, top_p=1.0)))
    np.testing.assert_array_equal(np.isfinite(out),
                                  [[True, False, False], [False, False, True]])


def test_min_p_threshold_is_relative_to_max():
    # probs ~ [0.665, 0.244, 0.090]; min_p=0.2 -> cutoff 0.133: drop last
    lf = _lf([[2.0, 1.0, 0.0]])
    out = np.asarray(filter_logits(lf, SamplerConfig(min_p=0.2, top_p=1.0)))
    np.testing.assert_array_equal(np.isfinite(out)[0], [True, True, False])
    # min_p <= p_min/p_max keeps everything
    out = np.asarray(filter_logits(lf, SamplerConfig(min_p=0.05, top_p=1.0)))
    assert np.isfinite(out).all()
    # min_p ~ 1 keeps only the argmax
    out = np.asarray(filter_logits(lf, SamplerConfig(min_p=0.99, top_p=1.0)))
    np.testing.assert_array_equal(np.isfinite(out)[0], [True, False, False])


def test_top_p_smallest_covering_set():
    # probs ~ [0.665, 0.244, 0.090]: top_p=0.7 needs the first two
    lf = _lf([[2.0, 1.0, 0.0]])
    out = np.asarray(filter_logits(lf, SamplerConfig(top_p=0.7)))
    np.testing.assert_array_equal(np.isfinite(out)[0], [True, True, False])


def test_filters_compose_and_never_empty_the_row():
    lf = _lf([[9.0, 0.1, 0.0, -0.2], [1.0, 1.0, 1.0, 1.0]])
    cfg = SamplerConfig(top_k=2, top_p=0.5, min_p=0.9)
    out = np.asarray(filter_logits(lf, cfg))
    assert np.isfinite(out).any(axis=-1).all()
    # row 0: the dominant token survives the stack of filters
    assert np.isfinite(out[0, 0])


def test_sample_respects_filters_and_padded_vocab():
    # vocab=3 of Vp=5; top_k=1 -> sampling must be deterministic argmax
    logits = _lf([[0.0, 4.0, 1.0, 99.0, 99.0]])
    cfg = SamplerConfig(temperature=1.0, top_p=1.0, top_k=1)
    toks = [int(sample(jax.random.PRNGKey(s), logits, 3, cfg)[0])
            for s in range(8)]
    assert toks == [1] * 8          # never a padded column, never a runner-up
