"""Masking math for the sampler's top-k / top-p / typical-p / min-p
filters."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.sampler import SamplerConfig, filter_logits, sample


def _lf(rows):
    return jnp.asarray(np.array(rows, np.float32))


def test_top_k_keeps_exactly_k():
    lf = _lf([[0.0, 3.0, 1.0, 2.0, -1.0]])
    out = filter_logits(lf, SamplerConfig(top_k=2, top_p=1.0))
    kept = np.isfinite(np.asarray(out))[0]
    np.testing.assert_array_equal(kept, [False, True, False, True, False])
    # surviving logits pass through unchanged
    assert float(out[0, 1]) == 3.0 and float(out[0, 3]) == 2.0


def test_top_k_off_and_oversized_are_noops():
    lf = _lf([[0.0, 3.0, 1.0]])
    for k in (0, 3, 10):
        out = filter_logits(lf, SamplerConfig(top_k=k, top_p=1.0))
        assert np.isfinite(np.asarray(out)).all()


def test_top_k_is_per_row():
    lf = _lf([[5.0, 1.0, 0.0], [0.0, 1.0, 5.0]])
    out = np.asarray(filter_logits(lf, SamplerConfig(top_k=1, top_p=1.0)))
    np.testing.assert_array_equal(np.isfinite(out),
                                  [[True, False, False], [False, False, True]])


def test_min_p_threshold_is_relative_to_max():
    # probs ~ [0.665, 0.244, 0.090]; min_p=0.2 -> cutoff 0.133: drop last
    lf = _lf([[2.0, 1.0, 0.0]])
    out = np.asarray(filter_logits(lf, SamplerConfig(min_p=0.2, top_p=1.0)))
    np.testing.assert_array_equal(np.isfinite(out)[0], [True, True, False])
    # min_p <= p_min/p_max keeps everything
    out = np.asarray(filter_logits(lf, SamplerConfig(min_p=0.05, top_p=1.0)))
    assert np.isfinite(out).all()
    # min_p ~ 1 keeps only the argmax
    out = np.asarray(filter_logits(lf, SamplerConfig(min_p=0.99, top_p=1.0)))
    np.testing.assert_array_equal(np.isfinite(out)[0], [True, False, False])


def test_top_p_smallest_covering_set():
    # probs ~ [0.665, 0.244, 0.090]: top_p=0.7 needs the first two
    lf = _lf([[2.0, 1.0, 0.0]])
    out = np.asarray(filter_logits(lf, SamplerConfig(top_p=0.7)))
    np.testing.assert_array_equal(np.isfinite(out)[0], [True, True, False])


def test_typical_p_keeps_smallest_typical_set():
    # uniform-ish distribution: every token is equally typical, so a high
    # typical_p keeps the prefix of the typicality order covering the mass
    lf = _lf([[1.0, 1.0, 1.0, 1.0]])
    out = np.asarray(filter_logits(lf, SamplerConfig(typical_p=0.6, top_p=1.0)))
    # |−log p − H| = 0 for ALL tokens of a uniform row: ties at the cutoff
    # all survive (same tie rule as top-k)
    assert np.isfinite(out).sum() == 4

    # peaked distribution: probs ~ [0.843, 0.114, 0.042]; H ~ 0.52 nats.
    # surprisals ~ [0.17, 2.17, 3.17] -> typicality order is argmax first;
    # typical_p=0.8 is covered by the top token alone
    lf = _lf([[3.0, 1.0, 0.0]])
    out = np.asarray(filter_logits(lf, SamplerConfig(typical_p=0.8, top_p=1.0)))
    np.testing.assert_array_equal(np.isfinite(out)[0], [True, False, False])


def test_typical_p_can_drop_argmax_but_never_empties():
    # a dominant token over a long flat tail: the tail's spread pushes the
    # entropy far above the argmax's surprisal, so the mid-rank runner-up
    # (surprisal ~ H) is MORE typical than the argmax — the one filter
    # allowed to drop the top token (it keeps the most typical one instead)
    lf = _lf([[6.0, 2.5] + [0.0] * 200])
    out = np.asarray(filter_logits(lf, SamplerConfig(typical_p=0.01, top_p=1.0)))
    kept = np.isfinite(out)[0]
    assert kept.any()                       # never empty
    assert kept[1] and not kept[0]          # runner-up is the typical one


def test_typical_p_off_is_noop_and_respects_prior_masks():
    lf = _lf([[2.0, 1.0, 0.0]])
    out = np.asarray(filter_logits(lf, SamplerConfig(typical_p=1.0, top_p=1.0)))
    assert np.isfinite(out).all()           # 1.0 = off
    # composed after top-k: the typicality distribution is computed over
    # the SURVIVORS, and already-masked tokens can never come back
    cfg = SamplerConfig(top_k=2, typical_p=0.99, top_p=1.0)
    out = np.asarray(filter_logits(_lf([[2.0, 1.0, 0.0, -1.0]]), cfg))
    assert not np.isfinite(out[0, 2]) and not np.isfinite(out[0, 3])


def test_filters_compose_and_never_empty_the_row():
    lf = _lf([[9.0, 0.1, 0.0, -0.2], [1.0, 1.0, 1.0, 1.0]])
    cfg = SamplerConfig(top_k=2, top_p=0.5, min_p=0.9)
    out = np.asarray(filter_logits(lf, cfg))
    assert np.isfinite(out).any(axis=-1).all()
    # row 0: the dominant token survives the stack of filters
    assert np.isfinite(out[0, 0])


def test_sample_respects_filters_and_padded_vocab():
    # vocab=3 of Vp=5; top_k=1 -> sampling must be deterministic argmax
    logits = _lf([[0.0, 4.0, 1.0, 99.0, 99.0]])
    cfg = SamplerConfig(temperature=1.0, top_p=1.0, top_k=1)
    toks = [int(sample(jax.random.PRNGKey(s), logits, 3, cfg)[0])
            for s in range(8)]
    assert toks == [1] * 8          # never a padded column, never a runner-up
