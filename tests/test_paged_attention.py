"""Page-table-native decode attention (kernels/paged_attention + the
``attn_impl != "gather"`` serving modes):

* kernel parity — interpret-mode Pallas and the XLA block-scan ref vs the
  dense pure-jnp oracle over GQA/MQA shapes, holes, partial pages, windows;
* the bit-exactness construction — paged (mapped pages only) == ring (all
  logical blocks) EXACTLY, per impl, at the op level: skipped fully-masked
  blocks are identity steps on the online-softmax carry (ref.py);
* serve-level A/B — ``serve()`` with the page-native path reproduces the
  ring backend's token streams, exit steps, and EAT trajectories
  bit-for-bit, through BOTH monitor tiers (self and proxy);
* mapped-count sync — the compacted page list the attention reads is
  re-derived from the allocator table at every push, across
  admit/retract/free;
* CLI smoke — ``launch.serve --cache paged --attn-impl xla`` end to end.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core.eat import make_probe
from repro.core.monitor import ReasoningMonitor
from repro.core.stopping import EATStopper
from repro.data.synthetic import ChainTask, Tokens
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.paged_attention.ops import (
    block_positions,
    paged_decode_attention,
    ring_decode_attention,
)
from repro.models import Model
from repro.serving.cache import CacheConfig
from repro.serving.engine import EngineConfig, ReasoningEngine
from repro.serving.proxy import ProxyConfig
from repro.serving.sampler import SamplerConfig
from repro.serving.scheduler import PageAllocator


# ------------------------------------------------------------- op-level setup


def make_paged_case(mapped, *, Hq=4, Hkv=2, Dk=16, Dv=16, ps=16, NB=16,
                    m=1, dtype=jnp.float32, seed=0):
    """A dense ring cache and an equivalent page pool holding the same
    written values, with per-row mapped-block patterns ``mapped`` (interior
    holes model admitted rows).  Pool pages are pre-filled with garbage so
    stale/unwritten slots differ between the two layouts — the masking
    discipline must cancel them exactly."""
    rng = np.random.default_rng(seed)
    B = len(mapped)
    C = NB * ps
    kd = np.zeros((B, C, Hkv, Dk), np.float32)
    vd = np.zeros((B, C, Hkv, Dv), np.float32)
    kv_pos = np.full((B, C), -1, np.int32)
    P = sum(len(bl) for bl in mapped) + 4
    kp = rng.normal(size=(P, ps, Hkv, Dk)).astype(np.float32)   # garbage
    vp = rng.normal(size=(P, ps, Hkv, Dv)).astype(np.float32)
    NBK = max(len(bl) for bl in mapped) + 2                     # padded ranks
    pages = np.zeros((B, NBK), np.int32)
    logical = np.zeros((B, NBK), np.int32)
    counts = np.array([len(bl) for bl in mapped], np.int32)
    nxt = 1
    for b, blocks in enumerate(mapped):
        for j, blk in enumerate(blocks):
            pages[b, j], logical[b, j] = nxt, blk
            fill = ps if blk != blocks[-1] else ps // 2 + 1     # partial last
            vk = rng.normal(size=(fill, Hkv, Dk)).astype(np.float32)
            vv = rng.normal(size=(fill, Hkv, Dv)).astype(np.float32)
            kp[nxt, :fill], vp[nxt, :fill] = vk, vv
            kd[b, blk * ps:blk * ps + fill] = vk
            vd[b, blk * ps:blk * ps + fill] = vv
            kv_pos[b, blk * ps:blk * ps + fill] = np.arange(
                blk * ps, blk * ps + fill)
            nxt += 1
    q = jnp.asarray(rng.normal(size=(B, m, Hq, Dk)), dtype)
    q_pos = jnp.asarray(
        np.stack([np.arange(C - m, C)] * B), jnp.int32)
    case = dict(
        q=q, q_pos=q_pos,
        kd=jnp.asarray(kd, dtype), vd=jnp.asarray(vd, dtype),
        kv_pos=jnp.asarray(kv_pos),
        kp=jnp.asarray(kp, dtype), vp=jnp.asarray(vp, dtype),
        pages=jnp.asarray(pages), logical=jnp.asarray(logical),
        counts=jnp.asarray(counts), ps=ps,
    )
    case["bpos"] = block_positions(case["kv_pos"], case["pages"],
                                   case["logical"], ps)
    return case


HOLES = [[0, 1, 2, 12], [0, 1, 2, 3, 4, 5], [0, 12, 13],
         [0, 1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12]]


@pytest.mark.parametrize("m", [1, 2, 5])
@pytest.mark.parametrize("window", [0, 40])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_kernel_matches_oracle(m, window, dtype):
    """Interpret-mode Pallas and the XLA block ref vs the dense oracle."""
    c = make_paged_case(HOLES, m=m, dtype=dtype)
    ref = attention_ref(c["q"], c["kd"], c["vd"], c["q_pos"], c["kv_pos"],
                        window=window, scale=0.25)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    for impl in ("xla", "pallas"):
        out = paged_decode_attention(
            c["q"], c["kp"], c["vp"], c["pages"], c["counts"], c["bpos"],
            c["q_pos"], window=window, scale=0.25, impl=impl, interpret=True)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32),
                                   atol=tol, rtol=tol, err_msg=impl)


@pytest.mark.parametrize("case", [
    dict(Hq=4, Hkv=2),            # GQA
    dict(Hq=8, Hkv=1),            # MQA
    dict(Hq=6, Hkv=3, Dk=32, Dv=16),   # Dv != Dk
])
def test_paged_equals_ring_bitwise(case):
    """THE construction the serving modes rely on: the paged op (mapped
    pages only) equals the ring op (all logical blocks) with EXACT float
    equality, per impl — skipped blocks are identity steps."""
    c = make_paged_case(HOLES, m=2, **case)
    for impl in ("xla", "pallas"):
        ring = ring_decode_attention(
            c["q"], c["kd"], c["vd"], c["q_pos"], c["kv_pos"],
            page_size=c["ps"], scale=0.25, impl=impl, interpret=True)
        paged = paged_decode_attention(
            c["q"], c["kp"], c["vp"], c["pages"], c["counts"], c["bpos"],
            c["q_pos"], scale=0.25, impl=impl, interpret=True)
        np.testing.assert_array_equal(np.asarray(ring), np.asarray(paged),
                                      err_msg=impl)


def test_ring_op_pads_non_multiple_capacity():
    """A ring capacity that is not a page multiple is padded with masked
    slots — appended identity steps, so the result is unchanged."""
    c = make_paged_case(HOLES, m=1)
    ref = ring_decode_attention(c["q"], c["kd"], c["vd"], c["q_pos"],
                                c["kv_pos"], page_size=16, scale=0.25,
                                impl="xla")
    odd = ring_decode_attention(
        c["q"], c["kd"][:, :-8], c["vd"][:, :-8], c["q_pos"],
        c["kv_pos"][:, :-8], page_size=16, scale=0.25, impl="xla")
    # the dropped tail slots are all pos=-1 in this case, so truncation +
    # re-padding must not change anything
    assert (np.asarray(c["kv_pos"])[:, -8:] == -1).all()
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(odd))


# ------------------------------------------------------------ serve-level A/B


def _engine(kind, attn, *, num_pages=0, capacity=256, delta=1e9, budget=24,
            proxy=False, chunk_len=8):
    cfg = get_config("tiny")
    model = Model(cfg, attn_impl="xla")
    params = model.init(jax.random.PRNGKey(11))
    ecfg = EngineConfig(
        max_reasoning_tokens=budget, capacity=capacity,
        pad_id=Tokens.PAD, end_think_id=Tokens.END_THINK,
        newline_id=Tokens.NEWLINE, eos_id=Tokens.EOS, chunk_len=chunk_len,
        sampler=SamplerConfig(greedy=True),
        cache=CacheConfig(kind=kind, page_size=16, num_pages=num_pages,
                          attn_impl=attn),
    )
    monitor = ReasoningMonitor(
        stopper=EATStopper(alpha=0.2, delta=delta),
        probe=make_probe(Tokens.END_THINK, (Tokens.ANS,)),
        schedule="every_n", every_n=4, min_evals=1,
    )
    px = ProxyConfig(model=model, params=params) if proxy else None
    return ReasoningEngine(model, params, ecfg, monitor, proxy=px)


def _serve(eng, b, **kw):
    return eng.serve(b["prompts"], b["prompt_len"], jax.random.PRNGKey(0),
                     batch_size=4, max_tokens=24, **kw)


def _assert_bit_equal(ref, out):
    for r, o in zip(ref, out):
        assert r["n_reasoning"] == o["n_reasoning"]
        assert r["exit_reason"] == o["exit_reason"]
        assert r["ended_think"] == o["ended_think"]
        np.testing.assert_array_equal(r["reasoning_tokens"],
                                      o["reasoning_tokens"])
        if "answer_tokens" in r and r["answer_tokens"] is not None:
            np.testing.assert_array_equal(r["answer_tokens"],
                                          o["answer_tokens"])
        assert r["eat_trace"] == o["eat_trace"]       # bit-exact floats


@pytest.fixture(scope="module")
def serve_batch():
    return ChainTask().serve_batch(np.random.default_rng(7), 6)


def test_page_native_serve_identical_to_ring(serve_batch):
    """The acceptance A/B: the page-native paged path reproduces the ring
    backend's token streams, exit steps, answers, and EAT trajectories
    bit-for-bit, both delta regimes."""
    b = serve_batch
    for delta in (1e9, 0.0):
        ref = _serve(_engine("ring", "xla", delta=delta), b,
                     answer_len=4, record_trace=True)
        out = _serve(_engine("paged", "xla", delta=delta), b,
                     answer_len=4, record_trace=True)
        _assert_bit_equal(ref, out)


def test_page_native_serve_with_admission_holes():
    """14 requests through a 24-data-page pool: admissions map prompt
    blocks + the current decode block, leaving interior unmapped holes the
    page-native read must skip — still bit-identical to the ring."""
    b = ChainTask().serve_batch(np.random.default_rng(9), 14)
    ref = _engine("ring", "xla", capacity=400, delta=0.0).serve(
        b["prompts"], b["prompt_len"], jax.random.PRNGKey(0),
        batch_size=4, max_tokens=24, record_trace=True)
    out = _engine("paged", "xla", capacity=400, num_pages=25,
                  delta=0.0).serve(
        b["prompts"], b["prompt_len"], jax.random.PRNGKey(0),
        batch_size=4, max_tokens=24, record_trace=True)
    _assert_bit_equal(ref, out)
    assert len(out) == 14


def test_page_native_proxy_tier_bit_exact(serve_batch):
    """Both monitor tiers through the new path: a same-params proxy serve
    (shadow decode + retract reconciliation, its own page pool read
    page-natively) reproduces self-EAT serving bit-for-bit, both
    backends."""
    b = serve_batch
    for kind in ("ring", "paged"):
        ref = _serve(_engine(kind, "xla", delta=0.2), b, record_trace=True)
        out = _serve(_engine(kind, "xla", delta=0.2, proxy=True), b,
                     record_trace=True)
        _assert_bit_equal(ref, out)


def test_pallas_interpret_serve_smoke():
    """The --attn-impl pallas path end to end on CPU (interpret mode): a
    short paged serve produces the same tokens and exit metadata as the
    XLA page-native path (allclose numerics -> identical greedy tokens)."""
    b = ChainTask().serve_batch(np.random.default_rng(3), 2)
    kw = dict(num_pages=0, capacity=64, delta=1e9, budget=8, chunk_len=4)
    ref = _engine("paged", "xla", **kw).serve(
        b["prompts"], b["prompt_len"], jax.random.PRNGKey(0), batch_size=2,
        max_tokens=8)
    out = _engine("paged", "pallas", **kw).serve(
        b["prompts"], b["prompt_len"], jax.random.PRNGKey(0), batch_size=2,
        max_tokens=8)
    for r, o in zip(ref, out):
        assert r["n_reasoning"] == o["n_reasoning"]
        assert r["exit_reason"] == o["exit_reason"]
        np.testing.assert_array_equal(r["reasoning_tokens"],
                                      o["reasoning_tokens"])


def test_gather_default_untouched(serve_batch):
    """attn_impl='gather' (the default) still takes the logical-view
    gather: no blocks arrays in the cache, and the program keys carry no
    impl suffix."""
    eng = _engine("paged", "gather")
    out = _serve(eng, serve_batch)
    assert len(out) == 6
    assert all(k[-1] == "paged" for k in eng.executor._programs
               if k[0] == "chunk")


def test_native_refuses_blockless_paged_cache():
    """A paged cache without the compacted page list under a page-native
    impl must fail at trace time — a silent gather fallback would split
    the per-impl paged==ring bit-exactness pairing."""
    import dataclasses

    from repro.serving.cache import alloc_paged_cache
    from repro.serving.executor import positions_for

    cfg = get_config("tiny")
    model = dataclasses.replace(Model(cfg, attn_impl="xla"),
                                paged_attn_impl="xla")
    params = model.init(jax.random.PRNGKey(0))
    cache = alloc_paged_cache(cfg, 2, 64, 16, 9)      # no block_bucket
    tok = jnp.zeros((2, 1), jnp.int32)
    pos = jnp.zeros((2, 1), jnp.int32)
    with pytest.raises(ValueError, match="compacted page list"):
        model.decode_step(params, tok, positions_for(cfg, pos), pos, cache)


def test_native_program_keys_carry_impl(serve_batch):
    """--attn-impl threads EngineConfig.cache -> executor program keys."""
    eng = _engine("paged", "xla")
    _serve(eng, serve_batch)
    kinds = {k[-1] for k in eng.executor._programs if k[0] == "chunk"}
    assert kinds == {"paged+xla"}
    assert eng.model.paged_attn_impl == "xla"     # baked into the model


# -------------------------------------------------------- mapped-count sync


def test_block_buckets_track_admit_and_free():
    """The compacted page list is a pure function of the allocator table,
    re-derived at every push — admit/free (and retract, which never
    unmaps) cannot desync it."""
    alloc = PageAllocator(num_pages=32, page_size=4, n_blocks=16, batch=3)
    alloc.ensure(0, 0, 11)                        # row 0: blocks 0..2
    alloc.ensure(1, 0, 3)                         # row 1: block 0
    w = alloc.bucket_width()
    pages, logical, counts = alloc.block_buckets(w)
    np.testing.assert_array_equal(counts, [3, 1, 0])
    assert (logical[0, :3] == [0, 1, 2]).all()
    assert (pages[counts == 0] == 0).all()        # padding = trash

    # harvest row 0, admit a new request into it: prompt blocks + the
    # batch's current decode block -> an interior hole in the mapping
    alloc.free_row(0)
    alloc.admit_row(0, prompt_slots=8, cur=40)    # blocks 0,1 + block 10
    pages, logical, counts = alloc.block_buckets(alloc.bucket_width())
    assert counts[0] == 3
    np.testing.assert_array_equal(logical[0, :3], [0, 1, 10])  # ascending
    assert (pages[0, :3] != 0).all()
    # counts always equal the table's nonzero row sums (the sync invariant)
    np.testing.assert_array_equal(counts, (alloc.table != 0).sum(1))


def test_executor_push_keeps_blocks_in_sync(serve_batch):
    """ensure_chunk_pages re-derives the device blocks from the allocator
    table whenever it is dirty: a freed row's ranks go back to trash, an
    admitted row's fresh mapping appears, counts follow."""
    b = serve_batch
    eng = _engine("paged", "xla")
    B, S = 4, b["prompts"].shape[1]
    st = eng.start(jnp.asarray(b["prompts"][:B]),
                   jnp.asarray(b["prompt_len"][:B]), jax.random.PRNGKey(1),
                   capacity=16)
    from repro.serving.cache import alloc_paged_cache, blocks_arrays

    alloc = PageAllocator(B * 16 + 1, 16, 16, B)
    for row in range(B):
        alloc.ensure(row, 0, S - 1)
    w = alloc.bucket_width()
    paged = alloc_paged_cache(eng.model.cfg, B, 256, 16, B * 16 + 1,
                              block_bucket=w)
    paged["blocks"] = blocks_arrays(*alloc.block_buckets(w))
    st = st._replace(cache=eng.executor.pack_paged(paged, st.cache,
                                                   alloc.table))

    alloc.free_row(2)
    st = eng.executor.ensure_chunk_pages(alloc, st, [0, 1, 3], 4)
    blk = jax.tree_util.tree_map(np.asarray, st.cache["blocks"])
    assert blk["count"][2] == 0
    assert (blk["pages"][2] == 0).all()
    np.testing.assert_array_equal(blk["count"],
                                  (alloc.table != 0).sum(1))
    np.testing.assert_array_equal(np.asarray(st.cache["page_table"]),
                                  alloc.table)

    # cur in a later block -> prompt blocks + a distinct decode block
    row_table = alloc.admit_row(2, S, cur=100)
    assert (row_table != 0).sum() >= 2
    st = eng.executor.ensure_chunk_pages(alloc, st, [0, 1, 2, 3], 4)
    blk = jax.tree_util.tree_map(np.asarray, st.cache["blocks"])
    assert blk["count"][2] == (alloc.table[2] != 0).sum()
    np.testing.assert_array_equal(blk["count"],
                                  (alloc.table != 0).sum(1))


# ----------------------------------------------------------------- CLI smoke


def test_serve_cli_attn_impl_smoke():
    """``launch.serve --cache paged --attn-impl xla`` end to end (random
    weights): the CLI path for the page-native read cannot rot."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--requests", "4",
         "--batch", "2", "--budget", "16", "--chunk", "4", "--arch", "tiny",
         "--cache", "paged", "--attn-impl", "xla", "--local"],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert r.returncode == 0, r.stdout + r.stderr
    assert "served 4 requests" in r.stdout, r.stdout
