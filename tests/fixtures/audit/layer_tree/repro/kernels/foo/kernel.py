# layering fixture: a kernel reaching up into the serving stack (seeded
# violation — kernels are leaves)
from repro.serving.executor import program  # noqa: F401
