# layering fixture: a pure-host module importing jax (seeded violation)
import jax
import numpy as np


def pick_slot(active):
    del jax
    return int(np.argmin(active))
