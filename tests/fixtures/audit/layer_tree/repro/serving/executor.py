# layering fixture: the jit owner — its jit sites must NOT be flagged
import jax

program = jax.jit(lambda x: x * 2)
