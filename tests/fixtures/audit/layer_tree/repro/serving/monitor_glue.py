# layering fixture: a serving module building jit programs outside the
# executor (seeded violation), once directly and once through aliasing
import jax

fast = jax.jit(lambda x: x + 1)
make = jax.jit
