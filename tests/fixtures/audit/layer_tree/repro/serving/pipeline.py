# layering fixture: a dispatch-only module blocking on device work
# (seeded violation) — once via the jax attribute, once via an alias
import jax


def harvest(snap):
    jax.block_until_ready(snap)
    wait = jax.block_until_ready
    return wait(snap)
