# layering fixture: the deleted shim, reintroduced (seeded violation)
