"""keys-pass fixture: an executor-like program store with one builder that
bakes a knob into the closure without keying it (seeded violation), one
that keys everything correctly, and one waived through KEY_EXEMPT."""
import jax

KEY_EXEMPT = {
    "waived": "fixture waiver: the knob cannot change within one store",
}


class MiniExec:
    def __init__(self, model):
        self.model = model
        self._programs = {}

    def _kind(self, cache):
        return "paged" if "page_table" in cache else "ring"

    def bad_chunk_program(self, state, use_monitor):
        # SEEDED VIOLATION: use_monitor is traced into fn but not keyed —
        # the second call with the other flag gets the first program
        key = ("chunk", int(state.active.shape[0]), self._kind(state.cache))
        if key not in self._programs:
            def fn(params, st):
                return st if use_monitor else (st, st)

            self._programs[key] = jax.jit(fn)
        return self._programs[key]

    def good_chunk_program(self, state, use_monitor):
        key = ("good", int(state.active.shape[0]), use_monitor,
               self._kind(state.cache))
        if key not in self._programs:
            def fn(params, st):
                return st if use_monitor else (st, st)

            self._programs[key] = jax.jit(fn)
        return self._programs[key]

    def waived_program(self, state, use_monitor):
        key = ("waived", int(state.active.shape[0]))
        if key not in self._programs:
            def fn(params, st):
                return st if use_monitor else (st, st)

            self._programs[key] = jax.jit(fn)
        return self._programs[key]
