"""pallas-pass fixture: one impure index map (closes over a non-static
array) and one soft masking fill (seeded violations), next to a clean
kernel that must not be flagged."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _body(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def bad_gather(x, idx):
    B, S = x.shape
    return pl.pallas_call(
        _body,
        grid=(B,),
        # SEEDED VIOLATION: the index map closes over the traced array idx
        in_specs=[pl.BlockSpec((1, S), lambda b: (idx[b], 0))],
        out_specs=pl.BlockSpec((1, S), lambda b: (b, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
    )(x)


def soft_mask(s, mask):
    # SEEDED VIOLATION: -1e9 leaves probability mass after softmax
    return jnp.where(mask, s, -1e9)


def clean_copy(x, block: int = 8):
    B, S = x.shape
    n = pl.cdiv(S, block)
    return pl.pallas_call(
        _body,
        grid=(B, n),
        in_specs=[pl.BlockSpec((1, block), lambda b, j: (b, j))],
        out_specs=pl.BlockSpec((1, block), lambda b, j: (b, j)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
    )(x)


def clean_mask(s, mask):
    _NEG_INF = -1e30
    return jnp.where(mask, s, _NEG_INF), jnp.where(mask, s, 0.0)
