"""Async (double-buffered) serve loop: equivalence + interleaving harness.

The overlapped pipeline (``serving.pipeline``) dispatches chunk N+1 before
harvesting chunk N, so its correctness claims are about *schedules*, not
just end states.  This suite pins both:

* bit-exactness — ``serve(overlap=True)`` reproduces the sync loop
  token-for-token under greedy sampling across the full backend matrix
  {ring, paged} x {self, proxy} x {exit-at-first-eval, run-to-budget},
  including exact float equality on the EAT traces and the forced answers;
* forced interleavings — ``PipelineHooks`` is the test seam: a hook that
  blocks on every snapshot at dispatch degenerates the pipeline to
  harvest-before-dispatch (the overlap must never be *required*), while a
  recorder hook proves the default schedule really is dispatch-ahead
  (chunk F+1 in flight before boundary F is read) and that proxy
  reconciliation lags by exactly one boundary;
* retract-under-overlap — proxy overshoot rewinds spanning a page
  boundary, and a harvested row's pages stay OUT of the allocator free
  list until the in-flight fence retires (``InFlightLedger`` fence
  bookkeeping), while page reuse across admissions still happens;
* the 4x2 (data x model) mesh — the same sync==async equivalence through
  GSPMD sharding, in a subprocess with 8 forced host devices (the CI
  multidevice job runs this file — see .github/workflows/ci.yml).
"""
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core.eat import make_probe
from repro.core.monitor import ReasoningMonitor
from repro.core.stopping import EATStopper
from repro.data.synthetic import ChainTask, Tokens
from repro.models import Model
from repro.serving.cache import CacheConfig
from repro.serving.engine import EngineConfig, ReasoningEngine
from repro.serving.pipeline import PipelineHooks
from repro.serving.proxy import ProxyConfig
from repro.serving.sampler import SamplerConfig


@pytest.fixture(scope="module")
def gen_model():
    model = Model(get_config("tiny"), attn_impl="xla")
    return model, model.init(jax.random.PRNGKey(11))


@pytest.fixture(scope="module")
def serve_batch():
    return ChainTask().serve_batch(np.random.default_rng(7), 6)


def _engine(gen_model, *, kind="ring", delta=1e9, proxy=False, capacity=320,
            num_pages=0, budget=24, page_size=16):
    """Greedy tiny engine matching tests/test_proxy_serve.py; greedy
    sampling is what makes sync==async bit-exact (overlap shifts the
    admission rng-split schedule by up to one boundary, which argmax
    ignores)."""
    model, params = gen_model
    ecfg = EngineConfig(
        max_reasoning_tokens=budget, capacity=capacity,
        pad_id=Tokens.PAD, end_think_id=Tokens.END_THINK,
        newline_id=Tokens.NEWLINE, eos_id=Tokens.EOS, chunk_len=8,
        sampler=SamplerConfig(greedy=True),
        cache=CacheConfig(kind=kind, page_size=page_size,
                          num_pages=num_pages),
    )
    monitor = ReasoningMonitor(
        stopper=EATStopper(alpha=0.2, delta=delta),
        probe=make_probe(Tokens.END_THINK, (Tokens.ANS,)),
        schedule="every_n", every_n=4, min_evals=1,
    )
    pcfg = ProxyConfig(model=model, params=params) if proxy else None
    return ReasoningEngine(model, params, ecfg, monitor, proxy=pcfg)


def _serve(engine, b, **kw):
    return engine.serve(b["prompts"], b["prompt_len"], jax.random.PRNGKey(0),
                        batch_size=4, max_tokens=24, answer_len=4,
                        record_trace=True, **kw)


def _assert_bit_exact(ref, out, tag):
    assert len(ref) == len(out), tag
    for r, o in zip(ref, out):
        t = (tag, r["request"])
        assert r["n_reasoning"] == o["n_reasoning"], t
        assert r["exit_reason"] == o["exit_reason"], t
        assert r["ended_think"] == o["ended_think"], t
        np.testing.assert_array_equal(r["reasoning_tokens"],
                                      o["reasoning_tokens"])
        np.testing.assert_array_equal(r["answer_tokens"], o["answer_tokens"])
        assert r["eat_trace"] == o["eat_trace"], t    # bit-exact floats
        assert o["latency_s"] > 0, t                  # per-request latency


# --------------------------------------------------------- the sync==async matrix
@pytest.mark.parametrize("kind", ["ring", "paged"])
@pytest.mark.parametrize("tier", ["self", "proxy"])
@pytest.mark.parametrize("delta", [1e9, 0.0])
def test_overlap_bit_exact_matrix(gen_model, serve_batch, kind, tier, delta):
    """serve(overlap=True) == serve() across both cache backends, both
    monitor tiers, and both exit regimes (exit-at-first-eval and
    run-to-budget) — token streams, exit steps/reasons, forced answers,
    and EAT traces all bit-equal."""
    eng = _engine(gen_model, kind=kind, delta=delta, proxy=(tier == "proxy"))
    ref = _serve(eng, serve_batch)
    out = _serve(eng, serve_batch, overlap=True)
    _assert_bit_exact(ref, out, (kind, tier, delta))
    # the pipeline drained: every fence retired, no page parked
    assert eng._ledger.quiescent


# -------------------------------------------------- forced adversarial schedules
class EagerBlockHooks(PipelineHooks):
    """Degenerate the pipeline to harvest-before-dispatch: block on every
    snapshot the moment it is dispatched, so boundary F is fully
    materialized before the loop proceeds — correctness must never depend
    on the overlap actually overlapping."""

    def __init__(self):
        self.blocked = 0

    def on_dispatch(self, fence, snap):
        np.asarray(snap["ints"])
        np.asarray(snap["var"])
        np.asarray(snap["tokens"])
        self.blocked += 1


class RecorderHooks(PipelineHooks):
    """Record the pipeline event order for schedule assertions."""

    def __init__(self):
        self.events = []

    def on_dispatch(self, fence, snap):
        self.events.append(("dispatch", fence))

    def on_retire(self, fence):
        self.events.append(("retire", fence))

    def on_observe(self, fence, pstate):
        self.events.append(("observe", fence))

    def on_retract(self, fence):
        self.events.append(("retract", fence))

    def on_harvest(self, fence, slots):
        self.events.append(("harvest", fence, tuple(slots)))

    def on_admit(self, fence, slot):
        self.events.append(("admit", fence, slot))

    def index(self, ev):
        return self.events.index(ev)


@pytest.mark.parametrize("kind,tier", [("ring", "self"), ("paged", "proxy")])
def test_harvest_before_dispatch_degenerate(gen_model, serve_batch, kind,
                                            tier):
    """The adversarial anti-schedule: a hook that blocks on each snapshot
    inside on_dispatch serializes the loop (chunk F is DONE before the
    host moves on).  Results must still be bit-identical to the sync
    loop."""
    eng = _engine(gen_model, kind=kind, proxy=(tier == "proxy"))
    ref = _serve(eng, serve_batch)
    hooks = EagerBlockHooks()
    out = _serve(eng, serve_batch, overlap=True, pipeline_hooks=hooks)
    _assert_bit_exact(ref, out, ("eager-block", kind, tier))
    assert hooks.blocked > 1


def test_default_schedule_is_dispatch_ahead(gen_model, serve_batch):
    """The default schedule really overlaps: chunk F+1 is dispatched
    BEFORE boundary F is read back, every boundary retires in dispatch
    order, and at least one harvest lands while a later chunk flies."""
    eng = _engine(gen_model, kind="paged")
    hooks = RecorderHooks()
    _serve(eng, serve_batch, overlap=True, pipeline_hooks=hooks)
    ev = hooks.events
    dispatched = [e[1] for e in ev if e[0] == "dispatch"]
    retired = [e[1] for e in ev if e[0] == "retire"]
    # every dispatched fence retires, strictly in order
    assert retired == sorted(dispatched)
    # dispatch-before-harvest: every non-final boundary F is read AFTER
    # chunk F+1 went out
    for f in retired:
        if ("dispatch", f + 1) in ev:
            assert hooks.index(("dispatch", f + 1)) < hooks.index(
                ("retire", f)), (f, ev)
    # at least one request was harvested while a later chunk was in flight
    overlapped_harvests = [
        e for e in ev if e[0] == "harvest"
        and ("dispatch", e[1] + 1) in ev
    ]
    assert overlapped_harvests, ev


def test_proxy_reconciliation_lags_one_boundary(gen_model, serve_batch):
    """monitor=proxy under overlap: the shadow observe and the lagged
    retract for chunk F happen after chunk F+1 was dispatched — the
    proxy's exit verdict lands exactly one boundary late, never earlier,
    never later."""
    eng = _engine(gen_model, proxy=True)
    hooks = RecorderHooks()
    _serve(eng, serve_batch, overlap=True, pipeline_hooks=hooks)
    ev = hooks.events
    observed = [e[1] for e in ev if e[0] == "observe"]
    assert observed, ev
    for f in observed:
        # observe(F) and retract(F) trail dispatch(F+1) when it exists
        if ("dispatch", f + 1) in ev:
            assert hooks.index(("dispatch", f + 1)) < hooks.index(
                ("observe", f)), (f, ev)
            assert hooks.index(("dispatch", f + 1)) < hooks.index(
                ("retract", f)), (f, ev)
        # ...and each verdict is applied before the NEXT boundary is read
        if ("retire", f + 1) in ev:
            assert hooks.index(("retract", f)) < hooks.index(
                ("retire", f + 1)), (f, ev)


# ------------------------------------------------------- retract under overlap
def test_retract_overshoot_spans_page_boundary(gen_model, serve_batch):
    """Deferred proxy retract whose rewind crosses a physical page edge:
    page_size=4 with chunk_len=8 makes every chunk span >= 2 pages, so the
    one-boundary-late rewind truncates across a page boundary.  Still
    bit-exact vs the sync loop (which retracts the same overshoot one
    boundary earlier)."""
    eng = _engine(gen_model, kind="paged", proxy=True, page_size=4)
    ref = _serve(eng, serve_batch)
    out = _serve(eng, serve_batch, overlap=True)
    _assert_bit_exact(ref, out, "overshoot-page-boundary")


class FenceGuardHooks(PipelineHooks):
    """At every harvest that lands while a chunk is in flight, assert the
    freed rows' pages are parked on the ledger — neither back on the free
    list (the in-flight chunk's captured page table still maps them) nor
    owned by any row."""

    def __init__(self, engine):
        self.engine = engine
        self.in_flight_harvests = 0
        self.allocs = set()

    def on_harvest(self, fence, slots):
        led = self.engine._ledger
        if not led.in_flight:
            return
        self.in_flight_harvests += 1
        assert led._pending, "in-flight harvest parked no pages"
        for pf, alloc, pages in led._pending:
            self.allocs.add(id(alloc))
            self._alloc = alloc
            owned = {p for row in alloc._owned for p in row}
            for p in pages:
                assert p not in alloc.free, (fence, p)
                assert p not in owned, (fence, p)


def test_freed_pages_wait_for_in_flight_fence(gen_model, serve_batch):
    """An exit-latched row freed while the next chunk is already
    dispatched: its pages must not re-enter circulation until that fence
    retires — and page reuse must still happen once it does (the deferred
    free feeds later mappings, it doesn't leak).  delta=0.0 keeps the
    second cohort decoding to the budget, so it maps fresh blocks AFTER
    the first cohort's parked pages re-entered the free list."""
    eng = _engine(gen_model, kind="paged", delta=0.0)
    hooks = FenceGuardHooks(eng)
    _serve(eng, serve_batch, overlap=True, pipeline_hooks=hooks)
    assert hooks.in_flight_harvests > 0         # the scenario actually ran
    assert eng._ledger.pages_deferred > 0
    assert eng._ledger.quiescent                # all parked pages released
    # the deferred pages came back: later admissions reused them
    assert hooks._alloc.pages_reused > 0
    assert hooks._alloc.pages_in_use == 0


# ------------------------------------------------------------------ 4x2 mesh
MESH_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np
from repro.configs.base import get_config
from repro.core.eat import make_probe
from repro.core.monitor import ReasoningMonitor
from repro.core.stopping import EATStopper
from repro.data.synthetic import ChainTask, Tokens
from repro.launch.mesh import make_device_ctx
from repro.models import Model
from repro.serving.cache import CacheConfig
from repro.serving.engine import EngineConfig, ReasoningEngine
from repro.serving.pipeline import PipelineHooks
from repro.serving.proxy import ProxyConfig
from repro.serving.sampler import SamplerConfig

assert len(jax.devices()) == 8, jax.devices()

def build(delta, cache_kind="ring", proxy=False):
    cfg = get_config("tiny")
    model = Model(cfg, make_device_ctx(4, 2), attn_impl="xla")
    params = model.init(jax.random.PRNGKey(11))
    ecfg = EngineConfig(
        max_reasoning_tokens=24, capacity=320,
        pad_id=Tokens.PAD, end_think_id=Tokens.END_THINK,
        newline_id=Tokens.NEWLINE, eos_id=Tokens.EOS, chunk_len=8,
        sampler=SamplerConfig(greedy=True),
        cache=CacheConfig(kind=cache_kind, page_size=16),
    )
    monitor = ReasoningMonitor(
        stopper=EATStopper(alpha=0.2, delta=delta),
        probe=make_probe(Tokens.END_THINK, (Tokens.ANS,)),
        schedule="every_n", every_n=4, min_evals=1,
    )
    pcfg = ProxyConfig(model=model, params=params) if proxy else None
    return ReasoningEngine(model, params, ecfg, monitor, proxy=pcfg)

b = ChainTask().serve_batch(np.random.default_rng(7), 6)

def serve(eng, **kw):
    return eng.serve(b["prompts"], b["prompt_len"], jax.random.PRNGKey(0),
                     batch_size=4, max_tokens=24, answer_len=4,
                     record_trace=True, **kw)

def check(ref, out, tag):
    for r, o in zip(ref, out):
        assert r["n_reasoning"] == o["n_reasoning"], (tag, r, o)
        assert r["exit_reason"] == o["exit_reason"], (tag, r, o)
        assert r["ended_think"] == o["ended_think"], (tag, r, o)
        np.testing.assert_array_equal(r["reasoning_tokens"],
                                      o["reasoning_tokens"])
        np.testing.assert_array_equal(r["answer_tokens"], o["answer_tokens"])
        assert r["eat_trace"] == o["eat_trace"], tag
    print("mesh overlap ==", tag, flush=True)

# both exit regimes on the default backend, then the backend x tier matrix
for delta in (1e9, 0.0):
    eng = build(delta)
    check(serve(eng), serve(eng, overlap=True), ("ring", "self", delta))
for kind, proxy in (("paged", False), ("ring", True), ("paged", True)):
    eng = build(1e9, cache_kind=kind, proxy=proxy)
    check(serve(eng), serve(eng, overlap=True),
          (kind, "proxy" if proxy else "self", 1e9))

# forced adversarial interleaving under GSPMD: block every snapshot at
# dispatch (harvest-before-dispatch degenerate) — still bit-exact
class EagerBlock(PipelineHooks):
    def on_dispatch(self, fence, snap):
        np.asarray(snap["ints"])
        np.asarray(snap["tokens"])

eng = build(1e9, cache_kind="paged", proxy=True)
check(serve(eng), serve(eng, overlap=True, pipeline_hooks=EagerBlock()),
      ("eager-block", "paged", "proxy"))
print("done")
"""


def test_mesh_overlap_equivalence_8dev():
    """sync == async on a 4x2 (data x model) mesh across both backends and
    both monitor tiers, plus a forced adversarial interleaving — in a
    subprocess with 8 simulated host devices (the device count is fixed at
    jax import)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run([sys.executable, "-c", MESH_SCRIPT],
                       capture_output=True, text=True, env=env, timeout=900)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "done" in r.stdout
